"""Serving bench cells (docs/serving.md): paged-KV continuous batching vs
the dense static-batch engine under a seeded burst arrival process, on an
8-virtual-device TP ring (subprocess — the parent keeps one device), for
both collective backends.

Rows:
- ``serve.paged_vs_dense.{barrier,cais}`` — paged-engine makespan (µs) with
  the dense makespan and speedup in ``derived``. The burst process is the
  adversarial case for static batching: a same-length prompt group spans
  bursts, so the dense engine stalls until its LAST member arrives while
  the paged engine admits and chunk-prefills work as it lands.
- ``serve.latency.{mode}`` — p50 TTFT (µs) with p99 TTFT, p50/p99
  per-token latency, tokens/sec/device and peak KV-block utilization in
  ``derived``.

Both engines are warmed first (same request shapes, arrivals zeroed) so the
timed runs compare steady-state serving, not jit compiles. Greedy outputs
are asserted token-identical between the engines before timing. The paged
engine runs ``TPConfig(planner="perfsim")`` — serve-period graphs go
through the plan cache under reports/plans/ like the training cells. With
``$REPRO_BENCH_JSON`` set the rows are APPENDED to any rows already in the
file (the sublayer bench writes first in CI), and the full latency reports
are written to ``$REPRO_SERVE_REPORT`` (default ``serve-latency.json``)
as the uploaded artifact. Wall-clock on CPU-emulated devices is
informational; the row schema, parity and makespan ordering are the
contract."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import dump_rows_json, emit, record

_CHILD = "_REPRO_SERVE_BENCH_CHILD"


def _serve_child() -> None:
    import jax

    from benchmarks.common import bench_tiny
    from repro import sharding
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.runtime import Runtime, TPConfig
    from repro.serve import (DenseEngine, Engine, LoadSpec, ServeConfig,
                             generate)

    mesh = sharding.make_mesh((1, 8), ("data", "model"))
    n_req, max_new, gap = (8, 4, 0.1) if bench_tiny() else (16, 8, 0.25)
    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=128, num_heads=8, num_kv_heads=8, head_dim=16,
        d_ff=256)
    spec = LoadSpec(kind="burst", num_requests=n_req, burst_size=4,
                    gap_s=gap, prompt_len_min=4, prompt_len_max=12,
                    max_new_tokens=max_new, seed=0)
    sc = ServeConfig(max_batch=4, s_max=32, block_size=4, prefill_chunk=8)
    reports = {}
    for mode in ("barrier", "cais"):
        rt = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                     tp=TPConfig(mode=mode, chunks=2, planner="perfsim"))
        model = build_model(cfg, rt)
        params = model.init(jax.random.key(0))
        pag = Engine(model, params, cfg, rt, sc, mesh=mesh)
        den = DenseEngine(model, params, cfg, rt, sc, mesh=mesh)
        assert pag._paged, "bench arch must take the paged path"

        def arrived_now(reqs):
            for r in reqs:
                r.arrival_time = 0.0
            return reqs

        # warm both engines (compiles the decode-only and mixed step shapes
        # / the per-length dense prefills), then assert greedy parity
        warm_p = pag.run(arrived_now(generate(spec, cfg.vocab_size)))
        warm_d = den.run(arrived_now(generate(spec, cfg.vocab_size)))
        assert [r.out_tokens for r in warm_p] == \
            [r.out_tokens for r in warm_d], f"greedy parity broken ({mode})"

        pag.run(generate(spec, cfg.vocab_size))
        t_paged = pag.last_report["makespan_s"]
        den.run(generate(spec, cfg.vocab_size))
        t_dense = den.last_report["makespan_s"]
        emit(f"serve.paged_vs_dense.{mode}", t_paged * 1e6,
             f"dense_us={t_dense * 1e6:.0f} "
             f"speedup={t_dense / t_paged:.2f}x burst={spec.burst_size}"
             f"x{n_req // spec.burst_size}")
        rep = pag.last_report
        emit(f"serve.latency.{mode}", rep["ttft_p50_ms"] * 1e3,
             f"ttft_p99_ms={rep['ttft_p99_ms']:.2f} "
             f"per_token_p50_ms={rep['per_token_p50_ms']:.2f} "
             f"per_token_p99_ms={rep['per_token_p99_ms']:.2f} "
             f"toks_per_s_per_dev={rep['tokens_per_sec_per_device']:.1f} "
             f"kv_util={rep['kv_block_utilization']:.2f} "
             f"prefix_hits={rep['prefix_hits']:.0f}")
        reports[f"paged.{mode}"] = pag.last_report
        reports[f"dense.{mode}"] = den.last_report
    out = os.environ.get("_REPRO_SERVE_REPORT_TMP")
    if out:
        with open(out, "w") as fh:
            json.dump(reports, fh, indent=1, sort_keys=True)


def run() -> None:
    if os.environ.get(_CHILD):
        _serve_child()
        dump_rows_json()        # child rows → the path the parent hands us
        return
    # append mode: keep whatever rows an earlier bench already put in the
    # committed JSON (CI runs sublayer first), then add the serve cells
    base = os.environ.get("REPRO_BENCH_JSON")
    if base and os.path.exists(base):
        with open(base) as fh:
            for row in json.load(fh):
                record(row["name"], row["us_per_call"], row["derived"])
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD] = "1"
    env.setdefault("PYTHONPATH", "src")
    report_path = os.environ.get("REPRO_SERVE_REPORT", "serve-latency.json")
    with tempfile.TemporaryDirectory() as td:
        env["REPRO_BENCH_JSON"] = os.path.join(td, "child-rows.json")
        env["_REPRO_SERVE_REPORT_TMP"] = os.path.join(td, "reports.json")
        out = subprocess.run(
            [sys.executable, "-c",
             "from benchmarks.serve_bench import run; run()"],
            capture_output=True, text=True, env=env, timeout=1800)
        sys.stdout.write(out.stdout)
        if out.returncode != 0:
            sys.stderr.write(out.stderr[-2000:])
            raise RuntimeError("serve bench failed")
        with open(env["REPRO_BENCH_JSON"]) as fh:
            for row in json.load(fh):
                record(row["name"], row["us_per_call"], row["derived"])
        with open(env["_REPRO_SERVE_REPORT_TMP"]) as fh:
            reports = json.load(fh)
    with open(report_path, "w") as fh:
        json.dump(reports, fh, indent=1, sort_keys=True)
    print(f"latency reports -> {report_path}")

    import jax

    from benchmarks.common import bench_tiny
    emit("meta.serve_env", 0.0,
         f"tiny={int(bench_tiny())} jax={jax.__version__} "
         f"platform={jax.default_backend()} "
         "note=cpu-emulated-makespans-informational")
    dump_rows_json()


if __name__ == "__main__":
    run()
