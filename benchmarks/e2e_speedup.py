"""Paper Fig. 11 — end-to-end speedup of CAIS over the nine baselines,
per Table-I model, training and inference (prefill), from the calibrated
fabric model. Emits ours vs the paper's reported geomeans."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import perfsim as ps


def run() -> None:
    f = ps.calibrated_fabric()
    tbl = ps.speedup_table(f=f)
    for model_name, row in tbl.items():
        t_cais = ps.run_model(ps.PAPER_MODELS[[m.name for m in
                              ps.PAPER_MODELS].index(model_name)],
                              ps.BASELINES["CAIS"], f)
        for baseline, speedup in row.items():
            emit(f"fig11.{model_name}.CAIS_over_{baseline}",
                 t_cais * 1e6, f"speedup={speedup:.2f}x")
    gm = {b: ps.geomean(tbl[m][b] for m in tbl)
          for b in next(iter(tbl.values()))}
    for b, v in gm.items():
        paper = ps.PAPER_GEOMEANS_TRAIN.get(b)
        emit(f"fig11.geomean.CAIS_over_{b}", 0.0,
             f"ours={v:.2f}x paper={paper if paper else 'n/a'}x")


if __name__ == "__main__":
    run()
