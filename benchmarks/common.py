"""Shared benchmark helpers: CSV row emission + wall-clock timing.

Rows printed via :func:`emit` are also collected in memory; a bench that
wants a machine-readable artifact (CI bench-smoke) calls
:func:`dump_rows_json`, which writes them to ``$REPRO_BENCH_JSON`` (or an
explicit path) as a JSON list of ``{name, us_per_call, derived}``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

_ROWS: list = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    """Collect a row without printing — for re-recording rows a subprocess
    bench already printed (its ``_ROWS`` lives in the child process)."""
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 3),
                  "derived": derived})


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: ``name,us_per_call,derived`` (also collected for JSON)."""
    print(f"{name},{us_per_call:.3f},{derived}")
    record(name, us_per_call, derived)


def dump_rows_json(path: Optional[str] = None) -> Optional[str]:
    """Write every row emitted so far to ``path`` (default:
    ``$REPRO_BENCH_JSON``); no-op when neither is set. Returns the path."""
    path = path or os.environ.get("REPRO_BENCH_JSON")
    if not path:
        return None
    with open(path, "w") as fh:
        json.dump(_ROWS, fh, indent=1)
    return path


def bench_tiny() -> bool:
    """CI bench-smoke mode: shrink shapes so the cell finishes in seconds."""
    return bool(os.environ.get("REPRO_BENCH_TINY"))


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (blocks on jax outputs)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
