"""Shared benchmark helpers: CSV row emission + wall-clock timing."""
from __future__ import annotations

import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (blocks on jax outputs)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
