"""Paper Fig. 17 — per-device throughput vs device count (weak scaling:
hidden dims grow with the ring, as the paper does) for CAIS and
CoCoNet-NVLS. Plus Table-II style scaled-down validation and Fig. 2
motivation (comm vs comp when scaling up)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import perfsim as ps


def run() -> None:
    f8 = ps.calibrated_fabric()

    # ---- Fig 17: weak scaling 8 -> 32 ----
    base_rate = {}
    for n in (8, 16, 32):
        cfg = dataclasses.replace(
            ps.LLAMA_7B, hidden=ps.LLAMA_7B.hidden * n // 8,
            ffn_hidden=ps.LLAMA_7B.ffn_hidden * n // 8)
        f = dataclasses.replace(f8, n=n)
        for pol in ("CAIS", "CoCoNet-NVLS"):
            t = ps.run_model(cfg, ps.BASELINES[pol], f)
            rate = n / t  # work grows ∝ n ⇒ per-device throughput ∝ n/t
            base_rate.setdefault(pol, rate)
            emit(f"fig17.{pol}.n{n}", t * 1e6,
                 f"per_device_throughput={100 * rate / base_rate[pol]:.1f}%")

    # ---- Table II: scaled-down validation (full vs half config) ----
    full = dataclasses.replace(ps.LLAMA_7B, hidden=8192, ffn_hidden=22528)
    half = dataclasses.replace(ps.LLAMA_7B, hidden=4096, ffn_hidden=11264)
    f_full = f8
    f_half = dataclasses.replace(f8, peak=f8.peak / 2)  # 50% SMs
    for name, cfg, fab in (("full", full, f_full), ("half", half, f_half)):
        t_cais = ps.run_model(cfg, ps.BASELINES["CAIS"], fab)
        t_tp = ps.run_model(cfg, ps.BASELINES["TP-NVLS"], fab)
        emit(f"tab2.{name}.CAIS_over_TP-NVLS", t_cais * 1e6,
             f"speedup={t_tp / t_cais:.2f}x (paper: full 1.43, half 1.40)")

    # ---- Fig 2: comm/comp when scaling up (strong scaling of LLaMA-7B) ----
    for n in (2, 4, 8, 16, 32):
        f = dataclasses.replace(f8, n=n)
        comp = ps.run_model(ps.LLAMA_7B, ps.BASELINES["TP-NVLS"],
                            dataclasses.replace(f, bw=1e30))
        tot = ps.run_model(ps.LLAMA_7B, ps.BASELINES["TP-NVLS"], f)
        comm = tot - comp
        emit(f"fig2.LLaMA-7B.n{n}", tot * 1e6,
             f"comm/comp={comm / comp:.2f}")


if __name__ == "__main__":
    run()
