"""Wall-clock microbench of the real JAX CAIS primitives vs barrier
collectives on an 8-virtual-device ring (subprocess — the parent keeps one
device). CPU timings are NOT TPU predictions; the derived column carries the
structural evidence (HLO collective census) alongside."""
from __future__ import annotations

import os
import re
import subprocess
import sys

_CHILD = "_REPRO_PRIM_BENCH_CHILD"


def _child() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import emit, time_fn
    from repro.core import primitives as prim
    from repro.core.primitives import CAISConfig

    ax = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((8,), ("model",), axis_types=ax)
    B, S, d, F = 4, 2048, 512, 512
    x = jax.random.normal(jax.random.key(0), (B, S, d), jnp.bfloat16)
    w = jax.random.normal(jax.random.key(1), (d, F), jnp.bfloat16)

    def census(fn, in_specs, out_specs, *args):
        txt = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False)).lower(*args).compile().as_text()
        return {k: len(re.findall(rf"= \S+ {k}\(", txt))
                for k in ("all-gather", "reduce-scatter", "all-reduce",
                          "collective-permute")}

    cais = CAISConfig(num_chunks=4, bidirectional=True)
    cases = [
        ("ag_gemm.barrier",
         lambda a, b: prim.barrier_ag_gemm(a, b, "model"),
         (P(None, "model", None), P(None, "model")), P(None, None, "model"),
         (x, w)),
        ("ag_gemm.cais",
         lambda a, b: prim.ag_gemm(a, b, "model", cais),
         (P(None, "model", None), P(None, "model")), P(None, None, "model"),
         (x, w)),
        ("gemm_rs.barrier",
         lambda a, b: prim.barrier_gemm_rs(a, b, "model"),
         (P(None, None, "model"), P("model", None)), P(None, "model", None),
         (x, w)),
        ("gemm_rs.cais",
         lambda a, b: prim.gemm_rs(a, b, "model", cais),
         (P(None, None, "model"), P("model", None)), P(None, "model", None),
         (x, w)),
    ]
    for name, fn, ins, outs, args in cases:
        jitted = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=ins,
                                       out_specs=outs, check_vma=False))
        us = time_fn(jitted, *args)
        c = census(fn, ins, outs, *args)
        emit(f"prim.{name}", us,
             f"hlo:ag={c['all-gather']} rs={c['reduce-scatter']} "
             f"ar={c['all-reduce']} cp={c['collective-permute']}")


def run() -> None:
    if os.environ.get(_CHILD):
        _child()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD] = "1"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.primitives_bench import run; run()"],
        capture_output=True, text=True, env=env, timeout=1200)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise RuntimeError("primitives bench failed")


if __name__ == "__main__":
    run()
