"""Wall-clock microbench of the real JAX CAIS primitives vs barrier
collectives on an 8-virtual-device ring (subprocess — the parent keeps one
device). CPU timings are NOT TPU predictions; the derived column carries the
structural evidence (HLO collective census) alongside."""
from __future__ import annotations

import os
import re
import subprocess
import sys

_CHILD = "_REPRO_PRIM_BENCH_CHILD"


def _child() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import bench_tiny, dump_rows_json, emit, time_fn
    from repro import sharding
    from repro.core import primitives as prim
    from repro.core.backends import CAISBackend, get_backend
    from repro.core.primitives import CAISConfig

    mesh = sharding.make_mesh((8,), ("model",))
    # REPRO_BENCH_TINY: CI smoke shapes — structure (HLO census) is
    # identical, only the timings shrink to seconds
    B, S, d, F = (2, 256, 128, 128) if bench_tiny() else (4, 2048, 512, 512)
    x = jax.random.normal(jax.random.key(0), (B, S, d), jnp.bfloat16)
    w = jax.random.normal(jax.random.key(1), (d, F), jnp.bfloat16)

    def census(fn, in_specs, out_specs, *args):
        txt = jax.jit(sharding.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False)).lower(*args).compile().as_text()
        return {k: len(re.findall(rf"= \S+ {k}\(", txt))
                for k in ("all-gather", "reduce-scatter", "all-reduce",
                          "collective-permute")}

    cais = CAISConfig(num_chunks=4, bidirectional=True)
    cases = [
        ("ag_gemm.barrier",
         lambda a, b: prim.barrier_ag_gemm(a, b, "model"),
         (P(None, "model", None), P(None, "model")), P(None, None, "model"),
         (x, w)),
        ("ag_gemm.cais",
         lambda a, b: prim.ag_gemm(a, b, "model", cais),
         (P(None, "model", None), P(None, "model")), P(None, None, "model"),
         (x, w)),
        ("gemm_rs.barrier",
         lambda a, b: prim.barrier_gemm_rs(a, b, "model"),
         (P(None, None, "model"), P("model", None)), P(None, "model", None),
         (x, w)),
        ("gemm_rs.cais",
         lambda a, b: prim.gemm_rs(a, b, "model", cais),
         (P(None, None, "model"), P("model", None)), P(None, "model", None),
         (x, w)),
    ]
    for name, fn, ins, outs, args in cases:
        jitted = jax.jit(sharding.shard_map(fn, mesh=mesh, in_specs=ins,
                                       out_specs=outs, check_vma=False))
        us = time_fn(jitted, *args)
        c = census(fn, ins, outs, *args)
        emit(f"prim.{name}", us,
             f"hlo:ag={c['all-gather']} rs={c['reduce-scatter']} "
             f"ar={c['all-reduce']} cp={c['collective-permute']}")

    # ---- compute-aware chunk planning: planned vs fixed chunking ---------
    # The cais backend picks num_chunks per collective from payload bytes
    # and ring size (coordination.plan); compare against static chunkings.
    be = get_backend("cais")
    payload = x.size * x.dtype.itemsize   # gathered global activation bytes
    planned_c = CAISBackend.plan_chunks(payload, ring=8)
    ag_specs = ((P(None, "model", None), P(None, "model")),
                P(None, None, "model"))
    for name, cfg_c in (("planned", CAISConfig(num_chunks=None)),
                        ("fixed2", CAISConfig(num_chunks=2)),
                        ("fixed4", CAISConfig(num_chunks=4)),
                        ("fixed16", CAISConfig(num_chunks=16))):
        fn = lambda a, b, c_=cfg_c: be.ag_gemm(a, b, "model", c_)
        jitted = jax.jit(sharding.shard_map(
            fn, mesh=mesh, in_specs=ag_specs[0], out_specs=ag_specs[1],
            check_vma=False))
        us = time_fn(jitted, x, w)
        extra = f"num_chunks={planned_c} (auto)" if name == "planned" \
            else f"num_chunks={cfg_c.num_chunks}"
        emit(f"prim.ag_gemm.chunks.{name}", us, extra)

    dump_rows_json()   # CI bench-smoke artifact ($REPRO_BENCH_JSON)


def run() -> None:
    if os.environ.get(_CHILD):
        _child()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD] = "1"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.primitives_bench import run; run()"],
        capture_output=True, text=True, env=env, timeout=1200)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise RuntimeError("primitives bench failed")


if __name__ == "__main__":
    run()
