"""Paper Fig. 15/16 — bandwidth utilization: per-sub-layer averages for
CAIS-Base / CAIS-Partial / CAIS, and the L2 utilization-over-time trace."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import perfsim as ps


def run() -> None:
    f = ps.calibrated_fabric()
    # Fig 15: average useful-byte utilization per sub-layer
    for which in ("L1", "L2", "L3", "L4"):
        for pol in ("CAIS-Base", "CAIS-Partial", "CAIS"):
            mk, busy = ps.run_sublayer(ps.LLAMA_7B, ps.BASELINES[pol], f,
                                       which=which)
            u = ps.useful_utilization(ps.BASELINES[pol], busy, mk)
            emit(f"fig15.LLaMA-7B.{which}.{pol}", mk * 1e6,
                 f"bw_util={100 * u:.1f}%")

    # Fig 16: utilization over time for L2
    for pol in ("CAIS-Base", "CAIS-Partial", "CAIS"):
        mk, busy = ps.run_sublayer(ps.LLAMA_7B, ps.BASELINES[pol], f, "L2")
        tr = ps.trace(busy, mk, bins=20)
        scale = 1.0 / ps.BASELINES[pol].traffic_mult
        series = " ".join(f"{100 * v * scale:.0f}" for v in tr)
        emit(f"fig16.LLaMA-7B.L2.trace.{pol}", mk * 1e6, f"util%=[{series}]")


if __name__ == "__main__":
    run()
