"""Deliverable (g): the three-term roofline table per (arch × shape), built
from the dry-run artifacts under reports/dryrun/ (single-pod mesh, per the
assignment). Also writes reports/roofline.md for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.hw import V5E

SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def model_flops(rec: dict) -> float:
    """MODEL_FLOPS convention: 6·N·D train (N=active params, D=tokens);
    2·N·D forward-only (prefill/decode)."""
    arch, shape = rec["arch"], rec["shape"]
    n_active = rec.get("active_params", 0)
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    tokens = seq * batch
    mult = 6 if SHAPE_KIND[shape] == "train" else 2
    return mult * n_active * tokens


def load(report_dir: str = "reports/dryrun", mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, f"*.{mesh}.json"))):
        rec = json.load(open(path))
        rows.append(rec)
    return rows


def run(report_dir: str = "reports/dryrun") -> None:
    rows = load(report_dir)
    if not rows:
        emit("roofline.missing", 0.0, f"no dry-run artifacts in {report_dir}")
        return
    md = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | MODEL_FLOPS/HLO | note |",
          "|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        tag = f"{rec['arch']}.{rec['shape']}"
        if rec["status"] == "skipped":
            emit(f"roofline.{tag}", 0.0, f"skipped: {rec['reason'][:40]}")
            md.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                      f"skipped | — | {rec['reason'][:60]} |")
            continue
        if rec["status"] != "ok":
            emit(f"roofline.{tag}", 0.0, f"ERROR {rec.get('error', '')[:60]}")
            continue
        r = rec["roofline"]
        mf = model_flops(rec)
        flops_dev = rec.get("hlo_analysis", rec["cost"])["flops"]
        hlo_global = flops_dev * rec["chips"]
        ratio = mf / hlo_global if hlo_global else 0.0
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline.{tag}", total * 1e6,
             f"c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s "
             f"x={r['collective_s']:.2e}s dom={r['dominant']} "
             f"useful={ratio:.2f}")
        md.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {ratio:.2f} | "
            f"{_note(r, ratio)} |")
    os.makedirs("reports", exist_ok=True)
    with open("reports/roofline.md", "w") as f:
        f.write("\n".join(md) + "\n")


def _note(r: dict, ratio: float) -> str:
    if r["dominant"] == "collective":
        return "decompose/overlap the dominant collective (CAIS mode)"
    if r["dominant"] == "memory":
        return "fuse/avoid HBM round-trips; bigger per-step tiles"
    if ratio < 0.4:
        return "compute-bound but low useful ratio: cut remat recompute"
    return "compute-bound: near the right wall"


if __name__ == "__main__":
    run()
