"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows.

  fig2    motivation: comm vs comp while scaling up      (scalability.py)
  fig11   e2e speedups over 9 baselines                  (e2e_speedup.py)
  fig12   sub-layer L1–L4 speedups                       (sublayer.py)
  fig13/14 merge-table/staging sensitivity               (merge_table.py)
  fig15/16 bandwidth utilization                         (bandwidth.py)
  fig17/tab2 scalability + scaled-down validation        (scalability.py)
  prim    real JAX primitive timings + HLO census        (primitives_bench.py)
  roofline three-term table from the dry-run artifacts   (roofline_report.py)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bandwidth, e2e_speedup, merge_table,
                            primitives_bench, roofline_report, scalability,
                            sublayer)

    print("name,us_per_call,derived")
    failures = 0
    for mod in (e2e_speedup, sublayer, merge_table, bandwidth, scalability,
                primitives_bench, roofline_report):
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{mod.__name__}.FAILED,0,", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
