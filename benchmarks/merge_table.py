"""Paper Fig. 13/14 — staging-buffer (merge-table analogue) requirements and
performance sensitivity.

Fig. 13(a): minimum per-step staging bytes needed per sub-layer payload,
with coordination (our chunk scheduler picks num_chunks) vs without (the
whole shard is in flight — the uncoordinated 250 KB/port regime).
Fig. 14: end-to-end time vs staging-buffer size for coordinated (CAIS) and
uncoordinated (CAIS-Base) schedules."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import coordination as coord
from repro.core import perfsim as ps


def run() -> None:
    f = ps.calibrated_fabric()
    # Fig 13(a): staging bytes per sub-layer across the three models
    for cfg in ps.PAPER_MODELS:
        m = cfg.batch * cfg.seq * cfg.hidden * cfg.dtype_bytes
        plan = coord.plan(m, ring=f.n)
        uncoord = coord.schedule_metrics(m, f.n, num_chunks=1)
        emit(f"fig13.{cfg.name}.staging_coordinated", 0.0,
             f"bytes={plan.staging_bytes} chunks={plan.num_chunks}")
        emit(f"fig13.{cfg.name}.staging_uncoordinated", 0.0,
             f"bytes={uncoord.staging_bytes}")
        emit(f"fig13.{cfg.name}.reduction", 0.0,
             f"{100 * (1 - plan.staging_bytes / uncoord.staging_bytes):.0f}%")

    # Fig 14: performance vs buffer size (more chunks = smaller buffer)
    for chunks in (1, 2, 4, 8, 16, 32):
        m = ps.LLAMA_7B.batch * ps.LLAMA_7B.seq * ps.LLAMA_7B.hidden * 2
        staging = int(m / f.n / chunks)
        t_cais = ps.run_model(ps.LLAMA_7B, ps.BASELINES["CAIS"], f,
                              chunks=chunks)
        t_base = ps.run_model(ps.LLAMA_7B, ps.BASELINES["CAIS-Base"], f,
                              chunks=chunks)
        emit(f"fig14.LLaMA-7B.staging_{staging}B.CAIS", t_cais * 1e6,
             f"chunks={chunks}")
        emit(f"fig14.LLaMA-7B.staging_{staging}B.CAIS-Base", t_base * 1e6,
             f"chunks={chunks} slowdown={t_base / t_cais:.2f}x")


if __name__ == "__main__":
    run()
