"""Paper Fig. 12 — sub-layer (L1–L4) speedups of CAIS over each baseline,
plus measured cells on an 8-virtual-device ring (subprocess — the parent
keeps one device): the whole-block dataflow graph (``sp_block``, one
shard_map, pass-2 seam fusion) against the PR-1 per-sub-layer composition
(``sp_attention`` + ``sp_ffn``), the period-level graph (``sp_period``,
2 blocks in ONE shard_map with the cross-block seam fused) against the
per-block ``sp_block`` composition, and the microbatch-split period
(``num_microbatches=2`` — two independent chains in one graph, pass-3
``overlap_asym`` across them) against the unsplit serialized period, and
the perfsim-planned period (``planner="perfsim"``, docs/planner.md)
against the same split period under the greedy planner, and the
graph-built backward (``TPConfig.graph_backward`` — the ``sp_period``
custom VJP, docs/training.md) against plain JAX autodiff of the executed
forward. With ``$REPRO_BENCH_JSON`` set, every row (including the
subprocess cells) is dumped as the JSON baseline the CI slow-suite
commits as ``BENCH_pr10.json`` — a ``meta.sublayer_env`` row records the shapes/mode
so baselines regenerated under different settings are not silently
compared. Measured cells run on CPU-emulated virtual devices, where
``collective_permute`` chains serialize (no real bidirectional links), so
wall-clock "speedups" there are informational — the overlap cells are the
hook for real-hardware runs, and the perfsim Fig. 12 rows model the paper's
hardware."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import dump_rows_json, emit, record
from repro.core import perfsim as ps

_CHILD = "_REPRO_SUBLAYER_BLOCK_CHILD"


def _block_child() -> None:
    import jax
    import jax.numpy as jnp

    import repro.models.transformer as tr
    from benchmarks.common import bench_tiny, time_fn
    from repro import sharding
    from repro.configs import get_arch
    from repro.core import tp as tp_mod
    from repro.core.primitives import CAISConfig

    mesh = sharding.make_mesh((1, 8), ("data", "model"))
    S, d, d_ff = (256, 128, 256) if bench_tiny() else (1024, 256, 512)
    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=8, num_kv_heads=8,
        head_dim=d // 8, d_ff=d_ff)
    params = tr.init_block(jax.random.key(0), "attn", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, S, d), jnp.float32)

    params2 = [tr.init_block(jax.random.key(i), "attn", cfg, jnp.float32)
               for i in (0, 2)]

    for mode in ("barrier", "cais"):
        tpc = tp_mod.TPContext(mesh=mesh, backend=mode,
                               cais=CAISConfig(num_chunks=2))

        fused = jax.jit(
            lambda x, tpc=tpc: tp_mod.sp_block(tpc, x, params, cfg,
                                               "attn")[0])

        def split(x, tpc=tpc):
            p, m, f = params, params["mixer"], params["ffn"]
            r1 = x + tp_mod.sp_attention(
                tpc, x, p["norm1"]["scale"], m["wq"], m["wk"], m["wv"],
                m["wo"], cfg)
            return r1 + tp_mod.sp_ffn(
                tpc, r1, p["norm2"]["scale"], f["w_up"], f.get("w_gate"),
                f["w_down"], cfg.act)

        t_fused = time_fn(fused, x)
        t_split = time_fn(jax.jit(split), x)
        emit(f"block.fused_vs_split.{mode}", t_fused,
             f"split_us={t_split:.0f} speedup={t_split / t_fused:.2f}x")

        # period-level graph (2 blocks, ONE shard_map, cross-block pass-2
        # seam fusion) vs the per-block sp_block composition
        period = jax.jit(
            lambda x, tpc=tpc: tp_mod.sp_period(
                tpc, x, params2, cfg, ("attn", "attn"))[0])

        def per_block(x, tpc=tpc):
            for p in params2:
                x, _ = tp_mod.sp_block(tpc, x, p, cfg, "attn")
            return x

        t_period = time_fn(period, x)
        t_pb = time_fn(jax.jit(per_block), x)
        emit(f"period.graph_vs_perblock.{mode}", t_period,
             f"perblock_us={t_pb:.0f} speedup={t_pb / t_period:.2f}x")

        # microbatch-split period (2 independent chains in ONE graph, pass 3
        # cross-pairs their RS/AG into overlap_asym) vs the same period
        # unsplit (straight line — fully serialized after pass-2 fusion)
        split2 = jax.jit(
            lambda x, tpc=tpc: tp_mod.sp_period(
                tpc, x, params2, cfg, ("attn", "attn"),
                num_microbatches=2)[0])
        t_split2 = time_fn(split2, x)
        emit(f"period.split_vs_unsplit.{mode}", t_split2,
             f"unsplit_us={t_period:.0f} speedup={t_period / t_split2:.2f}x")

        # perfsim-planned period (planner="perfsim": the pass-3 pairing
        # and chunking come from the simulated-makespan search, memoized in
        # the plan cache under reports/plans/ — the artifact the 8-device CI
        # job uploads) vs the same split period under the greedy planner
        tpc_p = tp_mod.TPContext(mesh=mesh, backend=mode,
                                 cais=CAISConfig(num_chunks=2),
                                 planner="perfsim")
        planned = jax.jit(
            lambda x, tpc=tpc_p: tp_mod.sp_period(
                tpc, x, params2, cfg, ("attn", "attn"),
                num_microbatches=2)[0])
        t_planned = time_fn(planned, x)
        emit(f"planner.perfsim_vs_greedy.{mode}", t_planned,
             f"greedy_us={t_split2:.0f} speedup={t_split2 / t_planned:.2f}x")

        # graph-built backward (TPConfig.graph_backward — sp_period's custom
        # VJP lowers the backward as a dataflow graph merged with the
        # forward, docs/training.md) vs JAX autodiff of the executed
        # forward graph, on a grad-of-sum-of-squares train-step proxy
        import dataclasses as _dc

        def grad_fn(tpc_):
            def loss(x, ps_):
                out, _ = tp_mod.sp_period(tpc_, x, ps_, cfg,
                                          ("attn", "attn"),
                                          num_microbatches=2)
                return jnp.sum(out * out)
            return jax.jit(jax.grad(loss, argnums=(0, 1)))

        t_graph = time_fn(grad_fn(tpc), x, params2)
        t_auto = time_fn(grad_fn(_dc.replace(tpc, graph_backward=False)),
                         x, params2)
        emit(f"train_step.graph_vs_autodiff.{mode}", t_graph,
             f"autodiff_us={t_auto:.0f} speedup={t_auto / t_graph:.2f}x")

        # hierarchical 2D-mesh TP (docs/topology.md): the same 1-block graph
        # on a tp_in × tp_out = 2 × 4 mesh (per-axis collective composition)
        # vs the flat 8-ring. The barrier row feeds the inter-tier
        # (bw2, alpha2) calibration fit (repro.plan.calibrate.TOPO_CELLS).
        tpc2d = tp_mod.TPContext(mesh=sharding.make_tp_mesh(2, 4),
                                 backend=mode, cais=CAISConfig(num_chunks=2))
        fused2d = jax.jit(
            lambda x, tpc=tpc2d: tp_mod.sp_block(tpc, x, params, cfg,
                                                 "attn")[0])
        t_2d = time_fn(fused2d, x)
        emit(f"topo.flat_vs_2d.{mode}", t_2d,
             f"flat_us={t_fused:.0f} ratio={t_2d / t_fused:.2f}x")

    # grouped-EP MoE (E < tp): experts sharded over tp_out only, all-to-all
    # never crossing tp_in — vs the flat ring's replicated-expert fallback
    cfg_moe = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=8, num_kv_heads=8,
        head_dim=d // 8, d_ff=d_ff, window=16)
    params_moe = tr.init_block(jax.random.key(3), "attn", cfg_moe,
                               jnp.float32)
    moe_ts = {}
    for label, mesh_m in (("grouped_ep", sharding.make_tp_mesh(2, 4)),
                          ("flat_tp", mesh)):
        tpc_m = tp_mod.TPContext(mesh=mesh_m, backend="cais",
                                 cais=CAISConfig(num_chunks=2))
        fn = jax.jit(lambda x, tpc=tpc_m: tp_mod.sp_moe_ffn(
            tpc, x, params_moe["norm2"]["scale"], params_moe["ffn"],
            cfg_moe)[0])
        moe_ts[label] = time_fn(fn, x)
    emit("moe.grouped_ep_vs_tp", moe_ts["grouped_ep"],
         f"flat_us={moe_ts['flat_tp']:.0f} "
         f"ratio={moe_ts['grouped_ep'] / moe_ts['flat_tp']:.2f}x")

    # MoE train step through the graph-built backward (route / a2a_ffn /
    # unroute adjoints with the aux cotangent, docs/training.md) vs JAX
    # autodiff of the executed forward, with an explicit 2-microbatch split
    # so pass 3 can pair one chain's backward grad-a2a/grad-RS against the
    # other chain's forward gathers (cross-direction overlap_asym). E=8 so
    # the flat 8-ring takes the period-graph MoE path (E % ring == 0).
    import dataclasses as _dc

    cfg_moe = cfg_moe.scaled(moe=_dc.replace(cfg_moe.moe, num_experts=8))
    params_moe = tr.init_block(jax.random.key(5), "attn", cfg_moe,
                               jnp.float32)
    for mode in ("barrier", "cais"):
        tpc_m = tp_mod.TPContext(mesh=mesh, backend=mode,
                                 cais=CAISConfig(num_chunks=2))

        def moe_grad_fn(tpc_):
            def loss(x, p):
                out, aux = tp_mod.sp_period(tpc_, x, [p], cfg_moe,
                                            ("attn",), num_microbatches=2)
                return jnp.sum(out * out) + jnp.sum(aux)
            return jax.jit(jax.grad(loss, argnums=(0, 1)))

        t_g = time_fn(moe_grad_fn(tpc_m), x, params_moe)
        t_a = time_fn(moe_grad_fn(_dc.replace(tpc_m, graph_backward=False)),
                      x, params_moe)
        emit(f"train_step.moe_graph_vs_autodiff.{mode}", t_g,
             f"autodiff_us={t_a:.0f} speedup={t_a / t_g:.2f}x")


def run() -> None:
    if os.environ.get(_CHILD):
        _block_child()
        dump_rows_json()        # child rows → the path the parent hands us
        return
    # measured cell first (subprocess owns the 8-device override). The
    # child dumps its rows as JSON to a temp path; the parent merges them so
    # dump_rows_json() ($REPRO_BENCH_JSON) covers the measured cells too.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD] = "1"
    env.setdefault("PYTHONPATH", "src")
    with tempfile.TemporaryDirectory() as td:
        env["REPRO_BENCH_JSON"] = os.path.join(td, "child-rows.json")
        out = subprocess.run(
            [sys.executable, "-c",
             "from benchmarks.sublayer import run; run()"],
            capture_output=True, text=True, env=env, timeout=1200)
        sys.stdout.write(out.stdout)
        if out.returncode != 0:
            sys.stderr.write(out.stderr[-2000:])
            raise RuntimeError("fused-block bench failed")
        with open(env["REPRO_BENCH_JSON"]) as fh:
            for row in json.load(fh):
                record(row["name"], row["us_per_call"], row["derived"])

    # provenance row: which shapes/platform produced these numbers, so a
    # committed baseline regenerated under other settings is identifiable
    import jax

    from benchmarks.common import bench_tiny
    emit("meta.sublayer_env", 0.0,
         f"tiny={int(bench_tiny())} jax={jax.__version__} "
         f"platform={jax.default_backend()} "
         "note=measured-cells-cpu-emulated-informational")

    f = ps.calibrated_fabric()
    for cfg in ps.PAPER_MODELS:
        for which in ("L1", "L2", "L3", "L4"):
            t_cais, _ = ps.run_sublayer(cfg, ps.BASELINES["CAIS"], f, which)
            for name in ("TP-NVLS", "SP-NVLS", "CoCoNet", "FuseLib", "T3",
                         "CoCoNet-NVLS", "FuseLib-NVLS", "T3-NVLS", "LADM",
                         "CAIS-Base"):
                t, _ = ps.run_sublayer(cfg, ps.BASELINES[name], f, which)
                emit(f"fig12.{cfg.name}.{which}.CAIS_over_{name}",
                     t_cais * 1e6, f"speedup={t / t_cais:.2f}x")
    dump_rows_json()


if __name__ == "__main__":
    run()
