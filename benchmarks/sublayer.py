"""Paper Fig. 12 — sub-layer (L1–L4) speedups of CAIS over each baseline."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import perfsim as ps


def run() -> None:
    f = ps.calibrated_fabric()
    for cfg in ps.PAPER_MODELS:
        for which in ("L1", "L2", "L3", "L4"):
            t_cais, _ = ps.run_sublayer(cfg, ps.BASELINES["CAIS"], f, which)
            for name in ("TP-NVLS", "SP-NVLS", "CoCoNet", "FuseLib", "T3",
                         "CoCoNet-NVLS", "FuseLib-NVLS", "T3-NVLS", "LADM",
                         "CAIS-Base"):
                t, _ = ps.run_sublayer(cfg, ps.BASELINES[name], f, which)
                emit(f"fig12.{cfg.name}.{which}.CAIS_over_{name}",
                     t_cais * 1e6, f"speedup={t / t_cais:.2f}x")


if __name__ == "__main__":
    run()
