"""Cost extraction from compiled/lowered artifacts.

* ``cost_summary(compiled)``   — FLOPs / bytes-accessed from cost_analysis()
  (per-device numbers: XLA analyzes the partitioned per-device module).
* ``collective_bytes(hlo)``    — per-device wire bytes, parsed from the HLO
  text: for every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute op, sum the operand sizes (cost_analysis does not
  account collectives — the assignment's method).
* ``roofline_terms(...)``      — the three-term roofline per DESIGN/spec:
      compute    = flops_dev / peak_flops
      memory     = bytes_dev / hbm_bw
      collective = coll_bytes_dev / (ici_links × link_bw)
  (per-device numerators ≡ the global formula divided through by chips).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.hw import HWSpec, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^=]*\)|[a-z0-9]+\[[\d,]*\]\S*)\s*"
    r"([\w\-]+)\((.*)\)", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device operand bytes of every collective op, by op kind.

    Handles both sync ops (`all-gather(...)`) and async pairs
    (`all-gather-start` — the `-done` is skipped to avoid double counting).
    """
    defs: Dict[str, int] = {}
    per_op: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}

    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op, args = m.groups()
        defs[name.lstrip("%")] = _shape_bytes(type_str)

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op, args = m.groups()
        base = None
        for k in COLLECTIVE_OPS:
            if op == k or op == k + "-start":
                base = k
                break
        if base is None or op.endswith("-done"):
            continue
        operands = re.findall(r"%?([\w.\-]+)", args.split("channel_id")[0])
        tot = 0
        for o in operands:
            if o in defs:
                tot += defs[o]
        if tot == 0:
            # operands may be inline-typed (older dumps): fall back to the
            # op's own result bytes
            tot = _shape_bytes(type_str)
        per_op[base] += tot
    per_op["total"] = sum(per_op[k] for k in COLLECTIVE_OPS)
    return per_op


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, int]:
    ms = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = int(getattr(ms, k, 0))
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    dominant: str

    def as_dict(self):
        return asdict(self)


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float,
                   hw: HWSpec = V5E) -> Roofline:
    compute = flops_dev / hw.peak_flops
    memory = bytes_dev / hw.hbm_bw
    coll = coll_bytes_dev / (hw.ici_links * hw.ici_bw)
    dom = max((("compute", compute), ("memory", memory),
               ("collective", coll)), key=lambda kv: kv[1])[0]
    return Roofline(compute, memory, coll, flops_dev, bytes_dev,
                    coll_bytes_dev, dom)
