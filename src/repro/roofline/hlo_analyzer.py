"""While-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
``lax.scan`` (our layer stacks, loss chunking, CAIS ring schedules) is
undercounted by its trip count — useless for a roofline. This analyzer walks
the post-optimization per-device HLO text and computes

  * flops       — 2·numel(result)·K for dots (K = contracted extent),
                  1/elem for elementwise math; while bodies × trip count
  * bytes       — operand+result bytes at fusion boundaries (fused
                  intermediates don't touch HBM — closer to TPU semantics
                  than cost_analysis' per-op accounting)
  * collectives — per-kind operand bytes (all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute), trip-
                  multiplied

Trip counts come from the while condition computation (the s32 loop bound
constant). Validated in tests/test_roofline.py against hand-computed scans.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ops that are pure metadata / no real data movement
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}

# ops whose operand/result bytes hit HBM even under perfect fusion
_MEM_OPS = {"dot", "convolution", "dynamic-slice", "dynamic-update-slice",
            "gather", "scatter", "concatenate", "copy", "sort", "pad",
            "reverse", "reduce", "reduce-window", "select-and-scatter",
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"}

# elementwise-ish ops: 1 flop per output element
_EW_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "select",
    "compare", "convert", "floor", "ceil", "sign", "cosine", "sine",
    "logistic", "and", "or", "xor", "not", "clamp", "remainder",
    "exponential-minus-one", "log-plus-one", "atan2", "erf",
    "round-nearest-afz", "round-nearest-even", "shift-left",
    "shift-right-logical", "shift-right-arithmetic",
}


def _parse_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or [1])
               for dt, dims in _parse_dims(type_str))


def _type_numel(type_str: str) -> int:
    return sum(math.prod(dims or [1]) for _, dims in _parse_dims(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)

    def by_name(self, name: str) -> Optional[Instr]:
        for i in self.instrs:
            if i.name == name:
                return i
        return None


# tuple types may contain /*index=N*/ comments — match parens lazily up to
# the following opcode, not by excluding '='
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\(.*?\)|[a-z0-9]+\[[\d,]*\]\S*))\s+"
    r"([\w\-]+)\((.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\(.*\))?.*\{\s*$")


_COLL_KEYS = COLLECTIVE_KINDS + ("cp_fwd", "cp_bwd")


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _COLL_KEYS})

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLL_KEYS:
            self.coll[k] += other.coll[k] * mult

    def total_coll(self) -> float:
        return sum(self.coll[k] for k in COLLECTIVE_KINDS)

    def wire_time_bytes(self) -> float:
        """Per-direction wire bytes: collective-permutes split by ring
        direction run on opposite full-duplex links concurrently (the CAIS
        bidirectional schedule); other collectives counted in full (XLA's
        internal schedule is opaque — conservative for the baseline)."""
        other = sum(self.coll[k] for k in COLLECTIVE_KINDS
                    if k != "collective-permute")
        return other + max(self.coll["cp_fwd"], self.coll["cp_bwd"])


class HLOAnalyzer:
    """mem_mode:
      * "fused"    — bytes counted only for ops that touch HBM under perfect
                     elementwise fusion (_MEM_OPS) + entry params/outputs.
                     TPU-faithful lower bound (CPU HLO wraps every
                     elementwise op in its own micro-fusion, so boundary
                     counting inflates ~10×).
      * "boundary" — bytes at every non-fused instruction + fusion
                     boundaries (upper bound; cost_analysis-like).
    """

    def __init__(self, hlo_text: str, mem_mode: str = "fused"):
        assert mem_mode in ("fused", "boundary")
        self.mem_mode = mem_mode
        self.comps: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Costs] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if cur is None:
                m = _COMP_RE.match(line)
                if m and "{" in line:
                    cur = Computation(m.group(1))
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur.name
                continue
            if line.strip() == "}":
                self.comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, type_str, op, args = m.groups()
                cur.instrs.append(Instr(name, type_str, op, args, line))
        if cur is not None:
            self.comps[cur.name] = cur

    # ------------------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for i in comp.instrs:
            if i.op == "constant" and i.type_str.startswith(("s32[]", "s64[]",
                                                             "u32[]")):
                m = re.search(r"constant\((\d+)\)", i.line)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    @staticmethod
    def _permute_fwd(instr: Instr) -> bool:
        """Ring direction from source_target_pairs: (i → i+1 mod n) pairs
        are the forward ring, (i → i−1) the backward ring."""
        m = re.search(r"source_target_pairs=\{(.*?)\}\}", instr.line)
        if not m:
            return True
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(0))
        if not pairs:
            return True
        fwd = sum(1 for s, t in pairs
                  if (int(s) + 1) % max(len(pairs), 1) == int(t) % max(len(pairs), 1))
        return fwd * 2 >= len(pairs)

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
        # lhs operand shape
        ops = re.findall(r"%([\w.\-]+)", instr.args)
        k = 1
        if ops:
            lhs = comp.by_name(ops[0])
            if lhs is not None:
                parsed = _parse_dims(lhs.type_str)
                if parsed:
                    dims = parsed[0][1]
                    for d in cdims:
                        if d < len(dims):
                            k *= dims[d]
        return 2.0 * _type_numel(instr.type_str) * max(k, 1)

    def _called(self, instr: Instr, attr: str) -> Optional[str]:
        m = re.search(rf"{attr}=%?([\w.\-]+)", instr.line)
        return m.group(1) if m else None

    # ------------------------------------------------------------------
    def comp_costs(self, name: str, fused: bool = False) -> Costs:
        key = f"{name}|{fused}"
        if key in self._memo:
            return self._memo[key]
        c = Costs()
        comp = self.comps.get(name)
        if comp is None:
            return c
        for i in comp.instrs:
            c.add(self.instr_costs(comp, i, fused))
        self._memo[key] = c
        return c

    def instr_costs(self, comp: Computation, i: Instr,
                    fused: bool = False) -> Costs:
        c = Costs()
        op = i.op
        if op in _FREE_OPS:
            return c

        if op == "while":
            body = self._called(i, "body")
            cond = self._called(i, "condition")
            trips = self._trip_count(cond) if cond else 1
            if body:
                c.add(self.comp_costs(body), trips)
            if cond:
                c.add(self.comp_costs(cond), trips)
            return c

        if op == "fusion":
            callee = self._called(i, "calls")
            if callee:
                inner = self.comp_costs(callee, fused=True)
                c.flops += inner.flops
                c.bytes += inner.bytes     # mem-ops inside the fusion
                for k in COLLECTIVE_KINDS:
                    c.coll[k] += inner.coll[k]
            if self.mem_mode == "boundary":
                c.bytes += self._io_bytes(comp, i)
            return c

        if op in ("call", "async-start", "custom-call"):
            callee = self._called(i, "to") or self._called(i, "calls")
            if callee:
                c.add(self.comp_costs(callee))
            c.bytes += 0 if fused else self._io_bytes(comp, i)
            return c

        if op == "conditional":
            for attr in ("true_computation", "false_computation"):
                callee = self._called(i, attr)
                if callee:
                    c.add(self.comp_costs(callee), 0.5)
            m = re.findall(r"branch_computations=\{([^}]*)\}", i.line)
            if m:
                names = re.findall(r"%?([\w.\-]+)", m[0])
                for n in names:
                    c.add(self.comp_costs(n), 1.0 / max(len(names), 1))
            c.bytes += 0 if fused else self._io_bytes(comp, i)
            return c

        base = op[:-len("-start")] if op.endswith("-start") else op
        if base in COLLECTIVE_KINDS and not op.endswith("-done"):
            b = self._operand_bytes(comp, i)
            c.coll[base] += b
            if base == "collective-permute":
                c.coll["cp_fwd" if self._permute_fwd(i) else "cp_bwd"] += b

        if op == "dot":
            c.flops += self._dot_flops(comp, i)
        elif op in _EW_FLOP_OPS:
            c.flops += _type_numel(i.type_str)
        elif op in ("reduce", "reduce-window"):
            # ~1 flop per input element
            c.flops += self._operand_numel(comp, i)
        elif op == "convolution":
            c.flops += 2 * _type_numel(i.type_str)  # lower bound

        if op.endswith("-done"):
            return c
        if self.mem_mode == "fused":
            if op in _MEM_OPS or (op.endswith("-start")
                                  and op[:-6] in _MEM_OPS):
                c.bytes += self._mem_bytes(comp, i)
        elif not fused:
            c.bytes += self._io_bytes(comp, i)
        return c

    # ------------------------------------------------------------------
    def _operand_names(self, i: Instr) -> List[str]:
        args = i.args.split("), ")[0] if ")," in i.args else i.args
        return re.findall(r"%([\w.\-]+)", args)

    def _operand_bytes(self, comp: Computation, i: Instr) -> int:
        tot = 0
        for n in self._operand_names(i):
            d = comp.by_name(n)
            if d is not None:
                tot += _type_bytes(d.type_str)
        return tot

    def _operand_numel(self, comp: Computation, i: Instr) -> int:
        tot = 0
        for n in self._operand_names(i):
            d = comp.by_name(n)
            if d is not None:
                tot += _type_numel(d.type_str)
        return tot

    def _io_bytes(self, comp: Computation, i: Instr) -> int:
        return self._operand_bytes(comp, i) + _type_bytes(i.type_str)

    def _mem_bytes(self, comp: Computation, i: Instr) -> int:
        """HBM traffic of a mem-op with slice-aware semantics: a
        dynamic-slice reads only the slice (not its source buffer); a
        dynamic-update-slice writes only the updated region (in-place on
        TPU); gather/scatter touch ~the transferred rows."""
        op = i.op
        if op in ("dynamic-slice", "gather", "pad", "reverse", "copy",
                  "concatenate"):
            return 2 * _type_bytes(i.type_str)
        if op in ("dynamic-update-slice", "scatter"):
            sizes = [_type_bytes(self.comps[comp.name].by_name(n).type_str)
                     for n in self._operand_names(i)
                     if comp.by_name(n) is not None]
            return 2 * min(sizes) if sizes else 2 * _type_bytes(i.type_str)
        return self._io_bytes(comp, i)

    # ------------------------------------------------------------------
    def entry_costs(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        c = Costs()
        c.add(self.comp_costs(self.entry))
        if self.mem_mode == "fused":
            # entry params read once + root result written once
            comp = self.comps[self.entry]
            for i in comp.instrs:
                if i.op == "parameter":
                    c.bytes += _type_bytes(i.type_str)
                if i.line.lstrip().startswith("ROOT"):
                    c.bytes += _type_bytes(i.type_str)
        return c


def analyze(hlo_text: str) -> Dict[str, float]:
    """Both memory accountings + flops + per-kind collective bytes."""
    a = HLOAnalyzer(hlo_text, mem_mode="fused")
    c = a.entry_costs()
    upper = HLOAnalyzer(hlo_text, mem_mode="boundary").entry_costs()
    out = {"flops": c.flops, "bytes": c.bytes, "bytes_upper": upper.bytes,
           "collective_total": c.total_coll(),
           "collective_wire": c.wire_time_bytes()}
    out.update({f"coll_{k}": v for k, v in c.coll.items()})
    return out
