from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SSMConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.configs.registry import ARCHS, get_arch, list_archs

__all__ = [
    "ArchConfig", "EncoderConfig", "MLAConfig", "MoEConfig", "RGLRUConfig",
    "SSMConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
    "shape_applicable", "ARCHS", "get_arch", "list_archs",
]
