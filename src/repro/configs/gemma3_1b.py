"""gemma3-1b — assigned architecture config.

[dense] gemma3-1b — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt;
unverified]. 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

GEMMA3_1B = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=("swa",) * 5 + ("attn",),  # 5 local : 1 global
    window=512,
    norm="rmsnorm",
    act="gelu",
    rope_theta=1_000_000.0,  # global layers (local layers use 10k upstream)
    tie_embeddings=True,
    # Hybrid local:global — long_500k runs with context-parallel KV for the
    # 4 global layers (~2.6 GB total at 500k) and window-bounded local KV.
    sub_quadratic=True,
)

CONFIG = GEMMA3_1B
