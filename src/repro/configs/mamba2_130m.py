"""mamba2-130m — assigned architecture config.

[ssm] mamba2-130m — SSD (state-space duality) [arXiv:2405.21060; unverified]
24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

MAMBA2_130M = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # d_inner / head_dim = 1536/64 (bookkeeping)
    num_kv_heads=24,
    d_ff=0,                  # attn-free, no separate FFN (mamba block only)
    vocab_size=50_280,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4, n_groups=1),
    norm="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,
)

CONFIG = MAMBA2_130M
