"""mixtral-8x7b — assigned architecture config.

[moe] mixtral-8x7b — 8 experts top-2, SWA [arXiv:2401.04088; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

MIXTRAL_8X7B = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    layer_pattern=("swa",),
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                  group_size=512),
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sub_quadratic=True,      # SWA bounds the KV cache to the window
)

CONFIG = MIXTRAL_8X7B
