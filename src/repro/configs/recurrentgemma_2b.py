"""recurrentgemma-2b — assigned architecture config.

[hybrid] recurrentgemma-2b — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf]
26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

RECURRENTGEMMA_2B = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "swa"),  # 2 recurrent : 1 local attn
    window=2048,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    norm="rmsnorm",
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    sub_quadratic=True,
)

CONFIG = RECURRENTGEMMA_2B
