"""paligemma-3b — assigned architecture config.

--------------------------------------------------------------------------
[vlm] paligemma-3b — SigLIP + gemma [arXiv:2407.07726; hf]
18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

PALIGEMMA_3B = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    layer_pattern=("attn",),
    num_prefix_tokens=256,   # 224px / 14 patch → 16×16 tokens (stub frontend)
    vision_width=1152,       # SigLIP-So400m width
    norm="rmsnorm",
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    sub_quadratic=False,
)

CONFIG = PALIGEMMA_3B
