"""whisper-tiny — assigned architecture config.

[audio] whisper-tiny — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified]. 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

WHISPER_TINY = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    layer_pattern=("attn",),
    encoder=EncoderConfig(num_layers=4, max_source_len=1500),
    norm="layernorm",
    act="gelu_mlp",          # whisper uses non-gated GELU MLP
    tie_embeddings=True,
    sub_quadratic=False,
)

CONFIG = WHISPER_TINY
