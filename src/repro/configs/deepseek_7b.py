"""deepseek-7b — assigned architecture config.

[dense] deepseek-7b — llama-arch [arXiv:2401.02954; hf]
30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

DEEPSEEK_7B = ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102_400,
    layer_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
)

CONFIG = DEEPSEEK_7B
