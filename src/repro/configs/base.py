"""Architecture / shape configuration for the CAIS-on-TPU framework.

Every assigned architecture is expressed as an :class:`ArchConfig`; shapes
(training / prefill / decode / long-context) are :class:`ShapeConfig`.
The model zoo in ``repro.models`` builds purely from these dataclasses —
no arch-specific code paths outside of the block types declared here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs for block families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    """Capacity-bounded top-k MoE (GShard-style dispatch, EP over `model`)."""

    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # Arctic: a small dense FFN runs in parallel (residual) with the MoE.
    dense_residual_d_ff: int = 0
    # Token group size for dispatch einsum (bounds the one-hot tensor).
    group_size: int = 512
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality, chunked dual form)."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (RecurrentGemma / Griffin)."""

    lru_width: int = 2560
    conv_width: int = 4
    block_width: int = 0  # 0 => d_model


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub: the
    input_specs provide precomputed frame embeddings (B, T_enc, d_model)."""

    num_layers: int = 4
    max_source_len: int = 1500


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

# Block kinds usable in `layer_pattern`:
#   "attn"    — full (causal) GQA/MQA attention
#   "swa"     — sliding-window attention (window = cfg.window)
#   "mla"     — multi-head latent attention
#   "ssm"     — Mamba-2 SSD mixer
#   "rglru"   — RG-LRU recurrent mixer
BLOCK_KINDS = ("attn", "swa", "mla", "ssm", "rglru")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # Per-layer mixer pattern, cycled over `num_layers`
    # e.g. ("swa",)*5 + ("attn",) for gemma3's 5 local : 1 global.
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # sliding window for "swa" blocks

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # enc-dec (whisper): decoder fields above; encoder stack below.
    encoder: Optional[EncoderConfig] = None
    # vlm (paligemma): number of prefix image tokens provided by the stub
    # frontend via input_specs (precomputed patch embeddings).
    num_prefix_tokens: int = 0
    vision_width: int = 0  # width of stub patch embeddings (projected in)

    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (gated) | gelu (gated) | gelu_mlp (non-gated)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    logits_softcap: float = 0.0

    # Whether the arch is eligible for the long_500k shape (sub-quadratic /
    # bounded-KV attention). Pure full-attention archs skip it (DESIGN.md §5).
    sub_quadratic: bool = False
    # Optimizer default (huge MoE archs use adafactor — DESIGN.md §6).
    optimizer: str = "adamw"

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder is not None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer block-kind list of length num_layers."""
        pat = self.layer_pattern
        kinds = tuple(pat[i % len(pat)] for i in range(self.num_layers))
        for k in kinds:
            assert k in BLOCK_KINDS, k
        return kinds

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline's
        MODEL_FLOPS = 6·N·D."""
        from repro.models.counting import arch_param_count

        return arch_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.counting import arch_param_count

        return arch_param_count(self, active_only=True)

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ArchConfig":
        """Reduced config of the same family for CPU smoke tests."""
        kw = dict(
            num_layers=max(2, len(self.layer_pattern)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            window=min(self.window, 8) if self.window else 0,
        )
        if self.mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                qk_rope_head_dim=8, v_head_dim=8)
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                group_size=16,
                dense_residual_d_ff=64 if self.moe.dense_residual_d_ff else 0)
        if self.ssm:
            kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                                  chunk_size=8, conv_width=4)
        if self.rglru:
            kw["rglru"] = RGLRUConfig(lru_width=64, conv_width=4)
        if self.encoder:
            kw["encoder"] = EncoderConfig(num_layers=2, max_source_len=16)
        if self.num_prefix_tokens:
            kw["num_prefix_tokens"] = 4
            kw["vision_width"] = 32
        return self.scaled(**kw)


# ---------------------------------------------------------------------------
# ShapeConfig — the assigned input-shape set
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, and the reason if skipped.

    Per the assignment: long_500k needs sub-quadratic attention — skipped for
    pure full-attention archs (noted in DESIGN.md §5); encoder-only archs have
    no decode step (none of our 10 are encoder-only: whisper's decoder
    decodes)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token KV cache is "
                       "unbounded (DESIGN.md §5)")
    return True, ""
