"""minicpm3-4b — assigned architecture config.

[dense] minicpm3-4b — MLA [hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

MINICPM3_4B = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,             # qk_nope + qk_rope = 64 + 32
    d_ff=6400,
    vocab_size=73_448,
    layer_pattern=("mla",),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    sub_quadratic=False,     # MLA compresses the cache but attention is full
)

CONFIG = MINICPM3_4B
