"""Assigned architecture registry.

One module per architecture under ``repro.configs.<arch_id>`` (dashes →
underscores); this registry collects them for ``--arch <id>`` selection.
Each module records [source; verified-tier] in its docstring.
"""
from __future__ import annotations

from repro.configs.arctic_480b import ARCTIC_480B
from repro.configs.base import ArchConfig
from repro.configs.deepseek_7b import DEEPSEEK_7B
from repro.configs.gemma3_1b import GEMMA3_1B
from repro.configs.internlm2_1_8b import INTERNLM2_1_8B
from repro.configs.mamba2_130m import MAMBA2_130M
from repro.configs.minicpm3_4b import MINICPM3_4B
from repro.configs.mixtral_8x7b import MIXTRAL_8X7B
from repro.configs.paligemma_3b import PALIGEMMA_3B
from repro.configs.recurrentgemma_2b import RECURRENTGEMMA_2B
from repro.configs.whisper_tiny import WHISPER_TINY

ARCHS = {
    a.name: a
    for a in (
        PALIGEMMA_3B,
        MAMBA2_130M,
        WHISPER_TINY,
        DEEPSEEK_7B,
        INTERNLM2_1_8B,
        GEMMA3_1B,
        MINICPM3_4B,
        RECURRENTGEMMA_2B,
        MIXTRAL_8X7B,
        ARCTIC_480B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
