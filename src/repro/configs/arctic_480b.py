"""arctic-480b — assigned architecture config.

[moe] arctic-480b — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
"""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

ARCTIC_480B = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual_d_ff=4864, group_size=512),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
    optimizer="adafactor",   # Adam f32 state for 480B params exceeds 512×16GB
)

CONFIG = ARCTIC_480B
