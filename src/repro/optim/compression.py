"""Int8 gradient compression with error feedback for the cross-pod DP
all-reduce (distributed-optimization trick, DESIGN.md §6).

Cross-pod DCI links are the scarcest bandwidth on a multi-pod mesh; gradient
all-reduce over `pod` moves the full parameter gradient every step. This
module quantizes each gradient tensor to int8 with a per-tensor scale before
the psum and dequantizes after — 4× less wire traffic — while an error
feedback (EF) buffer accumulates the quantization residual so the *averaged*
update stays unbiased over time (SGD-EF convergence guarantee).

Use inside shard_map over the DP axes (local per-device grads in, reduced
grads out):

    grads, ef = compressed_psum(local_grads, ef, axes=("pod",))
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, error_feedback, axes: Sequence[str],
                    mean: bool = True):
    """Quantized psum over `axes` with error feedback.

    Each tensor: x = g + ef; q = int8(x); wire = psum(q int32) (+ scales via
    f32 psum — negligible bytes); ef' = x − deq(q). Returns (reduced, ef')."""
    from repro.sharding import shard_map_axis_size

    n = 1
    for a in axes:
        n *= shard_map_axis_size(a)

    def one(g, ef):
        x = g.astype(jnp.float32) + ef
        # codes must share one scale across devices to be summable: agree on
        # the max scale first (a scalar pmax — negligible wire bytes)
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
        for a in axes:
            scale = jax.lax.pmax(scale, a)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        total = q.astype(jnp.int32)
        for a in axes:
            total = jax.lax.psum(total, a)
        reduced = total.astype(jnp.float32) * scale
        if mean:
            reduced = reduced / n
        new_ef = x - _dequantize(q, scale)
        return reduced.astype(g.dtype), new_ef

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def wire_bytes_saved(grads) -> Tuple[int, int]:
    """(f32 bytes, int8 bytes) per all-reduce — the 4× headline."""
    f32 = sum(x.size * 4 for x in jax.tree.leaves(grads))
    return f32, f32 // 4
