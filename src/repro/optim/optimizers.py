"""Raw optimizers (no optax): AdamW and Adafactor, plus LR schedules and
global-norm gradient clipping.

Interface:
    opt = make_optimizer(name, lr_schedule, **kw)
    state = opt.init(params)
    params, state = opt.apply(params, grads, state, step)

Optimizer state is a pytree mirroring params — the launcher shards it over
the DP axes (ZeRO-1) via sharding specs (see repro/launch/train.py).
Adafactor (factored second moment, no momentum) is the default for the
~0.5T-parameter MoE archs where Adam's f32 state exceeds the pod's HBM
(DESIGN.md §6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.full((), lr, jnp.float32)


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    apply: Callable[..., Tuple[Params, Any]]
    name: str = "opt"


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def apply(params, grads, state, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, apply, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------


def adafactor(lr: Schedule, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params)

    def apply(params, grads, state, step):
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        leaves, treedef = jax.tree.flatten(params)
        gleaves = treedef.flatten_up_to(grads)
        sleaves = treedef.flatten_up_to(state)
        outs = [upd(p, g, s) for p, g, s in zip(leaves, gleaves, sleaves)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = treedef.unflatten([o[1] for o in outs])
        return new_params, new_state

    return Optimizer(init, apply, "adafactor")


def make_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(name)
