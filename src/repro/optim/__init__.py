from repro.optim.compression import (
    compressed_psum,
    init_error_feedback,
    wire_bytes_saved,
)
from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    global_norm,
    make_optimizer,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "make_optimizer", "cosine_schedule",
    "constant_schedule", "clip_by_global_norm", "global_norm",
    "compressed_psum", "init_error_feedback", "wire_bytes_saved",
]
