"""Target-hardware constants (TPU v5e) shared by roofline + perfsim.

These are the numbers mandated for the roofline analysis:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link per direction
    ici_links: int = 1                # roofline term uses chips × link_bw
    hop_latency: float = 1e-6         # per collective-permute hop (s)
    vmem_bytes: int = 128 * 1024**2   # v5e VMEM per core (staging budget ref)
    hbm_bytes: int = 16 * 1024**3     # v5e HBM per chip


V5E = HWSpec()
