"""Target-hardware constants (TPU v5e) shared by roofline + perfsim.

These are the numbers mandated for the roofline analysis:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link per direction
    ici_links: int = 1                # roofline term uses chips × link_bw
    hop_latency: float = 1e-6         # per collective-permute hop (s)
    vmem_bytes: int = 128 * 1024**2   # v5e VMEM per core (staging budget ref)
    hbm_bytes: int = 16 * 1024**3     # v5e HBM per chip
    # Inter-node tier (the slow ``tp_out`` axis of a hierarchical 2D-TP
    # mesh — docs/topology.md). Defaults model a DCN-attached pod slice:
    # ~12.5 GB/s/dir per host and tens of microseconds per hop.
    dcn_bw: float = 12.5e9            # bytes/s per link per direction
    dcn_latency: float = 25e-6        # per inter-node hop (s)

    def inter_tier(self) -> "HWSpec":
        """This spec with the ICI link terms replaced by the inter-node
        tier's, so α-β consumers (``coordination.plan``) can be pointed at
        the slow axis without growing a second code path."""
        from dataclasses import replace
        return replace(self, ici_bw=self.dcn_bw, hop_latency=self.dcn_latency)


V5E = HWSpec()
