from repro.serve.engine import (DenseEngine, Engine, Request, ServeConfig,
                                paged_supported)
from repro.serve.kv import BlockAllocator, KVView, blocks_needed
from repro.serve.loadgen import (LoadSpec, format_report, generate,
                                 latency_report)
from repro.serve.scheduler import Row, Scheduler

__all__ = ["BlockAllocator", "DenseEngine", "Engine", "KVView", "LoadSpec",
           "Request", "Row", "Scheduler", "ServeConfig", "blocks_needed",
           "format_report", "generate", "latency_report", "paged_supported"]
