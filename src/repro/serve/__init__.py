from repro.serve.engine import Engine, Request, ServeConfig

__all__ = ["Engine", "Request", "ServeConfig"]
