"""Load generator + latency metrics for the serving benchmarks.

Arrival processes are seeded and fully deterministic (numpy ``default_rng``
— no wall clock enters generation), so a load-gen run is replayable
token-for-token together with the engine's fold_in sampling keys
(docs/serving.md). Two processes:

- ``poisson``: exponential inter-arrival gaps at ``rate`` requests/sec.
- ``burst``: ``num_requests // burst_size`` bursts, ``gap_s`` apart; every
  request in a burst arrives at the same instant. This is the adversarial
  case for a static-batch engine (it must serialize same-length groups)
  and the showcase for continuous batching.

Metrics are computed from per-request timestamps the engine records
(``t_first_token``, ``token_times`` — seconds relative to run start):
TTFT = first-token time − arrival time (includes queueing), per-token
latency = inter-token gaps after the first token, throughput =
total generated tokens / makespan / device count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

__all__ = ["LoadSpec", "generate", "latency_report", "format_report"]


@dataclass(frozen=True)
class LoadSpec:
    kind: str = "poisson"           # "poisson" | "burst"
    num_requests: int = 16
    rate: float = 8.0               # poisson: requests/sec
    burst_size: int = 4             # burst: requests per burst
    gap_s: float = 0.25             # burst: seconds between bursts
    prompt_len_min: int = 4
    prompt_len_max: int = 12
    max_new_tokens: int = 8
    temperature: float = 0.0
    seed: int = 0


def generate(spec: LoadSpec, vocab_size: int) -> List[object]:
    """Deterministic request list (arrival times set, prompts drawn from
    [1, vocab) so pad token 0 never appears in a prompt)."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(spec.seed)
    if spec.kind == "poisson":
        gaps = rng.exponential(1.0 / spec.rate, spec.num_requests)
        arrivals = np.cumsum(gaps) - gaps[0]          # first at t=0
    elif spec.kind == "burst":
        arrivals = np.array([(i // spec.burst_size) * spec.gap_s
                             for i in range(spec.num_requests)])
    else:
        raise ValueError(f"unknown arrival process: {spec.kind!r}")
    out = []
    for i in range(spec.num_requests):
        plen = int(rng.integers(spec.prompt_len_min, spec.prompt_len_max + 1))
        prompt = rng.integers(1, vocab_size, plen).astype(np.int32)
        out.append(Request(rid=i, prompt=prompt,
                           max_new_tokens=spec.max_new_tokens,
                           temperature=spec.temperature,
                           arrival_time=float(arrivals[i])))
    return out


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def latency_report(requests: List[object], makespan_s: float,
                   n_devices: int = 1,
                   kv_utilization: Optional[float] = None,
                   seed: Optional[int] = None) -> Dict[str, float]:
    """p50/p99 TTFT, p50/p99 per-token latency, tokens/sec/device,
    KV-block utilization — the committed bench-cell schema."""
    ttft = [r.t_first_token - r.arrival_time for r in requests
            if r.t_first_token is not None]
    per_tok: List[float] = []
    for r in requests:
        ts = r.token_times
        per_tok += [b - a for a, b in zip(ts, ts[1:])]
    total_tokens = sum(len(r.out_tokens) for r in requests)
    rep = {
        "num_requests": float(len(requests)),
        "total_tokens": float(total_tokens),
        "makespan_s": makespan_s,
        "ttft_p50_ms": _pct(ttft, 50) * 1e3,
        "ttft_p99_ms": _pct(ttft, 99) * 1e3,
        "per_token_p50_ms": _pct(per_tok, 50) * 1e3,
        "per_token_p99_ms": _pct(per_tok, 99) * 1e3,
        "tokens_per_sec_per_device":
            total_tokens / makespan_s / max(n_devices, 1)
            if makespan_s > 0 else 0.0,
    }
    if kv_utilization is not None:
        rep["kv_block_utilization"] = kv_utilization
    if seed is not None:
        rep["seed"] = float(seed)
    return rep


def format_report(rep: Dict[str, float]) -> str:
    keys = ("ttft_p50_ms", "ttft_p99_ms", "per_token_p50_ms",
            "per_token_p99_ms", "tokens_per_sec_per_device", "makespan_s")
    return " ".join(f"{k}={rep[k]:.2f}" for k in keys if k in rep)
