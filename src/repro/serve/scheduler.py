"""Continuous-batching scheduler over the paged KV cache.

Every engine iteration asks the scheduler for ONE mixed batch
(:meth:`Scheduler.next_batch`): all running decodes advance by one token
and whatever prefill work fits the remaining token budget rides along as
chunked-prefill rows — decode rows stay S=1, prefill rows feed up to
``prefill_chunk`` prompt tokens at their true positions. Both row kinds run
through the same ``LM.serve_step`` graph path (``sp_serve_period`` under
TP), so chunked prefill keeps the ragged ``gemm_ar`` route and decode stays
S=1 sharded. Requests retire the moment their last token is sampled and
their blocks return to the allocator (minus any the prefix cache keeps),
freeing admission capacity for the next iteration — the loop in
docs/serving.md.

Admission policy: a request is admitted only when (a) it has arrived,
(b) the active set is below ``max_active``, and (c) the allocator can
reserve its WORST-CASE block count up front (:func:`repro.serve.kv.
blocks_needed`, minus prefix-reused blocks) — so a running request can
never be starved of blocks mid-decode and there is no preemption path.
The scheduler is pure host-side bookkeeping: the engine owns device
arrays, sampling, and timing, and feeds sampled tokens back through
:meth:`feedback`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.serve.kv import BlockAllocator, blocks_needed

__all__ = ["Row", "Scheduler"]


@dataclass
class Row:
    """One request's slice of a mixed batch, in host (numpy) form."""
    rid: int
    tokens: np.ndarray        # (s,) int32 tokens fed this step
    positions: np.ndarray     # (s,) int32 KV positions they are written to
    context_len: int          # KV entries visible AFTER this step's writes
    block_table: List[int]
    sample: bool              # sample from this row's last-position logits?
    token_index: int          # which output token a sample would produce
    is_prefill: bool


@dataclass
class _Seq:
    req: object               # engine Request (duck-typed)
    block_ids: List[int]
    reuse_len: int            # prompt tokens already in the pool (prefix hit)
    written: int              # KV positions written so far
    tokens: np.ndarray        # prompt; sampled tokens are appended


class Scheduler:
    def __init__(self, alloc: BlockAllocator, *, max_batch: int,
                 prefill_chunk: int, token_budget: int, max_active: int):
        self.alloc = alloc
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget
        self.max_active = max_active
        self.waiting: List[object] = []
        self.active: List[_Seq] = []
        self._by_rid: Dict[int, _Seq] = {}

    # ----- lifecycle -----
    def submit(self, requests: List[object]) -> None:
        self.waiting.extend(requests)

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def admit(self, now: float) -> None:
        """Move arrived requests into the active set while capacity holds.
        FIFO: a request that cannot be admitted blocks later ones (no
        starvation of large requests)."""
        while self.waiting and len(self.active) < self.max_active:
            r = self.waiting[0]
            if getattr(r, "arrival_time", 0.0) > now:
                break
            prompt = np.asarray(r.prompt, np.int32)
            reused, reuse_len = self.alloc.match_prefix(prompt)
            need = blocks_needed(len(prompt), r.max_new_tokens,
                                 self.alloc.block_size) - len(reused)
            fresh = self.alloc.alloc(need) if need > 0 else []
            if fresh is None:
                self.alloc.release(reused)     # retry next iteration
                break
            seq = _Seq(req=r, block_ids=reused + fresh, reuse_len=reuse_len,
                       written=reuse_len, tokens=prompt)
            self.active.append(seq)
            self._by_rid[r.rid] = seq
            self.waiting.pop(0)

    # ----- batch construction -----
    def next_batch(self) -> List[Row]:
        """Decode rows for every running sequence first (1 token each),
        then chunked-prefill rows while the token budget lasts."""
        rows: List[Row] = []
        budget = self.token_budget
        for seq in self.active:
            if len(rows) >= self.max_batch or budget <= 0:
                break
            plen = len(np.asarray(seq.req.prompt))
            if seq.written < plen:
                continue                        # still prefilling
            t = seq.tokens[seq.written:seq.written + 1]
            rows.append(Row(
                rid=seq.req.rid, tokens=np.asarray(t, np.int32),
                positions=np.asarray([seq.written], np.int32),
                context_len=seq.written + 1, block_table=seq.block_ids,
                sample=True, token_index=len(seq.req.out_tokens),
                is_prefill=False))
            budget -= 1
        for seq in self.active:
            if len(rows) >= self.max_batch or budget <= 0:
                break
            plen = len(np.asarray(seq.req.prompt))
            if seq.written >= plen:
                continue
            c = min(self.prefill_chunk, plen - seq.written, budget)
            t = seq.tokens[seq.written:seq.written + c]
            rows.append(Row(
                rid=seq.req.rid, tokens=np.asarray(t, np.int32),
                positions=np.arange(seq.written, seq.written + c, dtype=np.int32),
                context_len=seq.written + c, block_table=seq.block_ids,
                sample=seq.written + c == plen, token_index=0,
                is_prefill=True))
            budget -= c
        return rows

    # ----- results -----
    def advance(self, rid: int, fed: int, sampled: Optional[int]) -> None:
        """Advance one row's state after its step ran: ``fed`` is the number
        of tokens the executed row carried, ``sampled`` the token drawn from
        its last-position logits (None for a mid-prompt prefill chunk).
        Retires the request when its token budget is spent."""
        seq = self._by_rid[rid]
        r = seq.req
        plen = len(np.asarray(r.prompt))
        before = seq.written
        seq.written += fed
        if before < plen <= seq.written:
            # prompt fully in the pool: publish its full blocks now, so
            # later arrivals sharing the prefix reuse them while this
            # request is still decoding
            self.alloc.register_prefix(np.asarray(r.prompt, np.int32),
                                       seq.block_ids)
        if sampled is not None:
            r.out_tokens.append(int(sampled))
            seq.tokens = np.concatenate(
                [seq.tokens, np.asarray([sampled], np.int32)])
            if len(r.out_tokens) >= r.max_new_tokens:
                self._retire(seq)

    def _retire(self, seq: _Seq) -> None:
        r = seq.req
        r.done = True
        # prefix entries (registered at prefill completion) keep their own
        # refs; this only drops the request's ownership
        self.alloc.release(seq.block_ids)
        self.active.remove(seq)
        del self._by_rid[r.rid]
