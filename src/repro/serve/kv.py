"""Paged KV-cache bookkeeping: block allocator, refcounts, prefix cache.

The device side of paged attention lives in :mod:`repro.models.attention`
(``init_kv_pool`` / ``paged_update`` / ``paged_lookup`` and the ``KVView``
seam the model reads and writes through). This module is the host side:
which pool blocks belong to which request. A :class:`BlockAllocator` hands
out fixed-size blocks from a free list, refcounts them so prefix-shared
blocks are freed exactly once, and keeps an LRU prefix cache mapping
token-prefix bytes to block lists so a new request whose prompt starts with
an already-prefilled prefix skips recomputing (and re-storing) those
blocks. Layout and policy are documented in docs/serving.md.

Invariants:
- a block's refcount = (#requests whose block table contains it) +
  (#prefix-cache entries that contain it); it returns to the free list only
  at zero.
- prefix reuse covers only FULL blocks and at most ``len(prompt) - 1``
  tokens (block-aligned), so every admitted request feeds at least one
  prompt token and shared blocks are never written again — no
  copy-on-write is needed.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.attention import KVView  # re-export: the narrow seam

__all__ = ["BlockAllocator", "KVView", "blocks_needed"]


def blocks_needed(prompt_len: int, max_new_tokens: int, block_size: int
                  ) -> int:
    """Worst-case blocks for one request: every KV position it can ever
    write. The final sampled token is never fed back, so the last written
    position is ``prompt_len + max_new_tokens - 2`` (prompt positions are
    ``0..prompt_len-1``; decode writes ``prompt_len..``)."""
    positions = prompt_len + max(max_new_tokens - 1, 0)
    return max(-(-positions // block_size), 1)


class BlockAllocator:
    """Free-list block allocator with refcounts and an LRU prefix cache."""

    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = True):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of size >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # pop() takes from the tail: reversed range hands out low ids first
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks
        self._cache: "OrderedDict[bytes, List[int]]" = OrderedDict()
        self.prefix_cache_enabled = prefix_cache
        self.prefix_hits = 0
        self.peak_used = 0

    # ----- accounting -----
    def num_free(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.num_blocks

    def _incref(self, ids: List[int]) -> None:
        for b in ids:
            self._ref[b] += 1

    def _decref(self, ids: List[int]) -> None:
        for b in ids:
            self._ref[b] -= 1
            assert self._ref[b] >= 0, f"double free of block {b}"
            if self._ref[b] == 0:
                self._free.append(b)

    # ----- allocation -----
    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh blocks at refcount 1, or None if the pool cannot satisfy
        the request even after evicting cache-only prefix entries (LRU
        first). Returning None (instead of raising) lets the scheduler
        simply defer admission until running requests retire."""
        while n > len(self._free) and self._cache:
            key, ids = self._cache.popitem(last=False)   # LRU
            self._decref(ids)
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._incref(out)
        self.peak_used = max(self.peak_used, self.num_blocks - len(self._free))
        return out

    def release(self, ids: List[int]) -> None:
        """Drop one request's ownership; blocks still referenced by the
        prefix cache (or another request) stay resident."""
        self._decref(ids)

    # ----- prefix cache -----
    def _key(self, tokens: np.ndarray, k: int) -> bytes:
        return np.asarray(tokens[:k * self.block_size], np.int32).tobytes()

    def match_prefix(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached block-aligned proper prefix of ``tokens``.
        Returns (block ids — increfed on behalf of the caller, reused token
        count). Reuse is capped at ``len(tokens) - 1`` so the request still
        feeds >= 1 token (the logits seed the first sampled token)."""
        if not self.prefix_cache_enabled:
            return [], 0
        k_max = (len(tokens) - 1) // self.block_size
        for k in range(k_max, 0, -1):
            ids = self._cache.get(self._key(tokens, k))
            if ids is not None:
                self._cache.move_to_end(self._key(tokens, k))
                self._incref(ids)
                self.prefix_hits += 1
                return list(ids), k * self.block_size
        return [], 0

    def register_prefix(self, tokens: np.ndarray, ids: List[int]) -> None:
        """Publish a fully-prefilled prompt's blocks: one cache entry per
        full-block prefix length (nested, so future prompts sharing fewer
        blocks still match). Each entry holds its own reference."""
        if not self.prefix_cache_enabled:
            return
        for k in range(1, len(tokens) // self.block_size + 1):
            key = self._key(tokens, k)
            if key not in self._cache:
                self._cache[key] = list(ids[:k])
                self._incref(ids[:k])
            else:
                self._cache.move_to_end(key)
