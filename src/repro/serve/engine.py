"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Requests enter a queue; the engine packs up to `max_batch` requests, runs one
shared prefill (left-padded to the longest prompt via position masking), then
steps decode for all active sequences, retiring finished ones and (greedy or
temperature) sampling. All compute goes through the model's jit'd
prefill/decode steps — the same ones the dry-run lowers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs.base import ArchConfig
from repro.core.backends import get_backend
from repro.runtime import Runtime


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    max_batch: int = 8
    s_max: int = 256


class Engine:
    def __init__(self, model, params, cfg: ArchConfig, rt: Runtime,
                 serve_cfg: ServeConfig = ServeConfig(), mesh=None,
                 extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rt = rt
        # resolve the collective backend up front: an unknown tp.mode fails
        # at engine construction, not deep inside the first jitted prefill
        self.backend = get_backend(rt.tp.mode)
        self.sc = serve_cfg
        self.mesh = mesh
        self.extras = extras or {}
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=serve_cfg.s_max))
        self._decode = jax.jit(model.decode_step)

    def _pack(self, requests: List[Request]):
        """Right-align prompts into one (B, S) batch (pad token 0; padding
        positions are masked out by per-request idx)."""
        S = max(len(r.prompt) for r in requests)
        B = len(requests)
        toks = np.zeros((B, S), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt   # left-pad
            lens[i] = len(r.prompt)
        return jnp.asarray(toks), jnp.asarray(lens), S

    def run(self, requests: List[Request], key=None) -> List[Request]:
        key = key if key is not None else jax.random.key(0)
        # group by prompt length: one prefill per group keeps positions exact
        # (no pad tokens leak into the KV cache)
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        with sharding.use_mesh(self.mesh):
            for _, group in sorted(by_len.items()):
                for i in range(0, len(group), self.sc.max_batch):
                    chunk = group[i:i + self.sc.max_batch]
                    key, sub = jax.random.split(key)
                    self._run_batch(chunk, sub)
        return requests

    def _run_batch(self, requests: List[Request], key):
        toks, lens, S = self._pack(requests)
        batch = {"tokens": toks, **self.extras}
        logits, caches = self._prefill(self.params, batch)
        prefix = self.cfg.num_prefix_tokens
        idx = jnp.full((len(requests),), S + prefix, jnp.int32)
        tok = self._sample(logits[:, -1], requests, key)

        max_new = max(r.max_new_tokens for r in requests)
        for t in range(max_new):
            for i, r in enumerate(requests):
                if not r.done and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in requests):
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok, caches, idx + t)
            tok = self._sample(logits[:, -1], requests, sub)
        for r in requests:
            r.done = True

    def _sample(self, logits, requests: List[Request], key):
        greedy = jnp.argmax(logits, -1)
        temp = jnp.asarray([max(r.temperature, 1e-6) for r in requests])
        sampled = jax.random.categorical(key, logits / temp[:, None], -1)
        use_greedy = jnp.asarray([r.temperature == 0.0 for r in requests])
        out = jnp.where(use_greedy, greedy, sampled)
        return out.astype(jnp.int32)[:, None]
