"""Serving engines: paged-KV continuous batching (``Engine``) and the
static-batch dense-KV reference (``DenseEngine``).

``Engine`` is the production path (docs/serving.md): a
:class:`repro.serve.kv.BlockAllocator` owns fixed-size KV blocks with
prefix reuse, a :class:`repro.serve.scheduler.Scheduler` builds one mixed
prefill+decode batch per iteration (chunked prefill interleaved with
decode under a token budget), and every iteration runs ONE jitted
``LM.serve_step`` — under TP that is the ``sp_serve_period`` graph, where
chunked-prefill rows (S % tp ≠ 0) and S=1 decode rows alike keep tensor
parallelism through backend-dispatched ``gemm_ar``. Batches are padded to
(``max_batch``, S-bucket) so the engine compiles exactly two step shapes
(decode-only S=1, mixed S=``prefill_chunk``).

``DenseEngine`` is the pre-paging engine kept as the parity/bench
reference: dense ``(B, s_max)`` KV caches, one static batch per
same-length group, no admission between steps. Greedy decoding is pinned
token-for-token identical between the two (tests/test_serve.py).

Sampling is replayable: ``run(requests, key=None)`` resolves a seed
(recorded on every request), and each sampled token uses
``fold_in(fold_in(key(seed), rid), token_index)`` — independent of batch
composition and scheduling order, so a load-gen run replays exactly.
Archs the paged path cannot serve (ssm/rglru/mla mixers, enc-dec,
prefix-token VLMs) transparently fall back to the dense engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs.base import ArchConfig
from repro.core.backends import get_backend
from repro.models.attention import KVView
from repro.runtime import Runtime
from repro.serve.kv import BlockAllocator, blocks_needed
from repro.serve.scheduler import Row, Scheduler


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # load-gen / metrics surface (seconds, relative to run start)
    arrival_time: float = 0.0
    t_first_token: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    seed: Optional[int] = None          # sampling seed recorded by run()


@dataclass(frozen=True)
class ServeConfig:
    """Frozen so a config can never become cross-engine shared mutable
    state (the old mutable default bug). 0 means "derive a default"."""
    max_batch: int = 8
    s_max: int = 256
    block_size: int = 8                 # KV tokens per pool block
    num_blocks: int = 0                 # 0: max_active tables + slack
    prefill_chunk: int = 8              # prompt tokens per prefill row
    token_budget: int = 0               # 0: max_batch * prefill_chunk
    max_active: int = 0                 # 0: max_batch
    prefix_cache: bool = True


def _resolve_seed(key) -> int:
    if key is None:
        return 0
    if isinstance(key, (int, np.integer)):
        return int(key)
    return int(np.asarray(jax.random.key_data(key)).ravel()[-1])


def _sample_token(logits_row: np.ndarray, seed: int, rid: int,
                  token_index: int, temperature: float) -> int:
    """One token from one row's logits. Greedy at temperature 0; otherwise
    the key depends only on (seed, rid, token_index) — replayable no matter
    how requests were batched or scheduled."""
    if temperature <= 0.0:
        return int(np.argmax(logits_row))
    k = jax.random.fold_in(jax.random.fold_in(jax.random.key(seed), rid),
                           token_index)
    return int(jax.random.categorical(
        k, jnp.asarray(logits_row) / temperature))


def paged_supported(model, cfg: Optional[ArchConfig],
                    extras: Optional[Dict[str, Any]] = None) -> bool:
    """Can this (model, arch) serve through the paged path? Requires
    attention-only mixers (paged pools hold K/V blocks; ssm/rglru/mla carry
    other state), a decoder-only LM (``serve_step``), and no prefix/extras
    inputs (enc-dec cross-attention, VLM patch embeddings)."""
    if cfg is None or extras:
        return False
    if not hasattr(model, "serve_step"):
        return False
    if getattr(cfg, "is_enc_dec", False) or cfg.num_prefix_tokens:
        return False
    return all(k in ("attn", "swa") for k in cfg.layer_kinds())


class Engine:
    """Paged-KV continuous-batching engine (falls back to
    :class:`DenseEngine` for archs outside the paged path)."""

    def __init__(self, model, params, cfg: ArchConfig, rt: Runtime,
                 serve_cfg: Optional[ServeConfig] = None, mesh=None,
                 extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rt = rt
        # resolve the collective backend up front: an unknown tp.mode fails
        # at engine construction, not deep inside the first jitted step
        self.backend = get_backend(rt.tp.mode)
        self.sc = serve_cfg if serve_cfg is not None else ServeConfig()
        self.mesh = mesh
        self.extras = extras or {}
        self.last_report: Dict[str, float] = {}
        self._paged = paged_supported(model, cfg, self.extras)
        self._dense: Optional[DenseEngine] = None
        if self._paged:
            sc = self.sc
            self.max_active = sc.max_active or sc.max_batch
            self.table_width = max(-(-sc.s_max // sc.block_size), 1)
            self.num_blocks = sc.num_blocks or (
                self.max_active * self.table_width + self.table_width)
            self.token_budget = sc.token_budget or (
                sc.max_batch * sc.prefill_chunk)
            self._step = jax.jit(model.serve_step)
        else:
            self._dense = DenseEngine(model, params, cfg, rt, self.sc,
                                      mesh=mesh, extras=self.extras)

    # ----- batching -----
    def _assemble(self, rows: List[Row], s_pad: int):
        B = self.sc.max_batch
        toks = np.zeros((B, s_pad), np.int32)
        pos = np.full((B, s_pad), -1, np.int32)   # -1: no KV write, masked q
        bt = np.zeros((B, self.table_width), np.int32)
        ctx = np.zeros((B,), np.int32)            # 0: padding row, all masked
        last = np.zeros((B,), np.int32)
        for i, row in enumerate(rows):
            s = len(row.tokens)
            toks[i, :s] = row.tokens
            pos[i, :s] = row.positions
            bt[i, :len(row.block_table)] = row.block_table
            ctx[i] = row.context_len
            last[i] = s - 1
        view = KVView(block_tables=jnp.asarray(bt),
                      positions=jnp.asarray(pos),
                      context_lens=jnp.asarray(ctx),
                      last=jnp.asarray(last))
        return jnp.asarray(toks), view

    # ----- main loop -----
    def run(self, requests: List[Request], key=None) -> List[Request]:
        if not self._paged:
            return self._dense.run(requests, key=key)
        seed = _resolve_seed(key)
        sc = self.sc
        for r in requests:
            r.seed = seed
            need = blocks_needed(len(r.prompt), r.max_new_tokens,
                                 sc.block_size)
            if need > self.table_width:
                raise ValueError(
                    f"request {r.rid}: prompt+max_new needs {need} blocks, "
                    f"table holds {self.table_width} (raise s_max)")
        alloc = BlockAllocator(self.num_blocks, sc.block_size,
                               prefix_cache=sc.prefix_cache)
        sched = Scheduler(alloc, max_batch=sc.max_batch,
                          prefill_chunk=sc.prefill_chunk,
                          token_budget=self.token_budget,
                          max_active=self.max_active)
        sched.submit(requests)
        with sharding.use_mesh(self.mesh):
            pools = self.model.init_pools(self.num_blocks, sc.block_size)
            t0 = time.monotonic()
            while sched.has_work():
                now = time.monotonic() - t0
                sched.admit(now)
                rows = sched.next_batch()
                if not rows:
                    nxt = min(r.arrival_time for r in sched.waiting)
                    time.sleep(min(max(nxt - now, 0.0), 0.05) + 1e-4)
                    continue
                s_pad = 1 if all(not r.is_prefill for r in rows) \
                    else sc.prefill_chunk
                toks, view = self._assemble(rows, s_pad)
                logits, pools = self._step(self.params, toks, pools, view)
                logits = np.asarray(logits[:, 0])
                t_now = time.monotonic() - t0
                for i, row in enumerate(rows):
                    if not row.sample:
                        sched.advance(row.rid, len(row.tokens), None)
                        continue
                    req = next(r for r in requests if r.rid == row.rid)
                    tok = _sample_token(logits[i], seed, row.rid,
                                        row.token_index, req.temperature)
                    if req.t_first_token is None:
                        req.t_first_token = t_now
                    req.token_times.append(t_now)
                    sched.advance(row.rid, len(row.tokens), tok)
        makespan = time.monotonic() - t0
        from repro.serve.loadgen import latency_report
        self.last_report = latency_report(
            requests, makespan, n_devices=jax.device_count(),
            kv_utilization=alloc.peak_used / alloc.num_blocks, seed=seed)
        self.last_report["prefix_hits"] = float(alloc.prefix_hits)
        return requests


class DenseEngine:
    """The pre-paging static-batch engine: dense ``(B, s_max)`` KV caches,
    one batch per same-length prompt group, kept as the greedy-parity and
    makespan baseline for the paged engine."""

    def __init__(self, model, params, cfg: ArchConfig, rt: Runtime,
                 serve_cfg: Optional[ServeConfig] = None, mesh=None,
                 extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.backend = get_backend(rt.tp.mode)
        self.sc = serve_cfg if serve_cfg is not None else ServeConfig()
        self.mesh = mesh
        self.extras = extras or {}
        self.last_report: Dict[str, float] = {}
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=self.sc.s_max))
        self._decode = jax.jit(model.decode_step)

    def _pack(self, requests: List[Request]):
        """Right-align prompts into one (B, S) batch (pad token 0; padding
        positions are masked out by per-request idx)."""
        S = max(len(r.prompt) for r in requests)
        B = len(requests)
        toks = np.zeros((B, S), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt   # left-pad
            lens[i] = len(r.prompt)
        return jnp.asarray(toks), jnp.asarray(lens), S

    def run(self, requests: List[Request], key=None) -> List[Request]:
        seed = _resolve_seed(key)
        for r in requests:
            r.seed = seed
        # group by prompt length: one prefill per group keeps positions exact
        # (no pad tokens leak into the KV cache). A static batch cannot start
        # until every member has arrived — the cost continuous batching
        # removes.
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        t0 = time.monotonic()
        with sharding.use_mesh(self.mesh):
            for _, group in sorted(by_len.items()):
                for i in range(0, len(group), self.sc.max_batch):
                    chunk = group[i:i + self.sc.max_batch]
                    wait = max(r.arrival_time for r in chunk) \
                        - (time.monotonic() - t0)
                    if wait > 0:
                        time.sleep(wait)
                    self._run_batch(chunk, seed, t0)
        makespan = time.monotonic() - t0
        from repro.serve.loadgen import latency_report
        self.last_report = latency_report(requests, makespan,
                                          n_devices=jax.device_count(),
                                          seed=seed)
        return requests

    def _run_batch(self, requests: List[Request], seed: int, t0: float):
        toks, lens, S = self._pack(requests)
        batch = {"tokens": toks, **self.extras}
        logits, caches = self._prefill(self.params, batch)
        prefix = self.cfg.num_prefix_tokens
        idx = jnp.full((len(requests),), S + prefix, jnp.int32)
        tok = self._sample(logits[:, -1], requests, seed)

        max_new = max(r.max_new_tokens for r in requests)
        for t in range(max_new):
            t_now = time.monotonic() - t0
            for i, r in enumerate(requests):
                if not r.done and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
                    if r.t_first_token is None:
                        r.t_first_token = t_now
                    r.token_times.append(t_now)
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in requests):
                break
            logits, caches = self._decode(self.params, tok, caches, idx + t)
            tok = self._sample(logits[:, -1], requests, seed)
        for r in requests:
            r.done = True

    def _sample(self, logits, requests: List[Request], seed: int):
        rows = np.asarray(logits)
        out = [_sample_token(rows[i], seed, r.rid, len(r.out_tokens),
                             r.temperature)
               for i, r in enumerate(requests)]
        return jnp.asarray(out, jnp.int32)[:, None]
