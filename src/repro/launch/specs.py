"""Sharding specs + ShapeDtypeStruct input builders for the dry-run and
the real launchers.

`input_specs(arch, shape)` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input of the (arch × shape) cell — no device
allocation. `param_shardings` / `state_shardings` / `cache_shardings` map
the corresponding pytrees onto the production mesh (Megatron TP/SP rules +
EP for MoE + optional FSDP and ZeRO-1 over the DP axes; DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import build_model
from repro.runtime import Runtime, TPConfig

B_AX = sharding.BATCH_AXES      # ("pod", "data")
D_AX = sharding.DATA_AXIS
M_AX = sharding.MODEL_AXIS

# column-parallel (output dim -> model) / row-parallel (input dim -> model)
_COL = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b",
        "w_y", "w_x", "vision_proj", "lm_head"}
_ROW = {"wo", "w_out"}


def runtime_for(cfg: ArchConfig, tp_mode: str = "auto",
                cais_chunks: Optional[int] = None,
                tp_microbatches="auto",
                tp_planner: str = "greedy") -> Runtime:
    """Per-arch runtime defaults for the production meshes. ``tp_mode`` is
    any registered collective backend name; ``cais_chunks=None`` lets the
    cais backend plan the chunking per collective; ``tp_microbatches``
    defaults to ``"auto"`` so production periods split into independent
    microbatch chains (pass-3 ``overlap_asym``) whenever the planner says
    the per-chain payload stays latency-healthy — except MoE periods,
    which ``"auto"`` never splits (their aux loss is a per-batch statistic
    the split would change; pass an explicit int to opt in).
    ``tp_planner="perfsim"`` opts the period optimizer into the
    :mod:`repro.plan` simulated-makespan search (``"greedy"`` default)."""
    param_dtype = "bfloat16" if cfg.param_count() > 6e10 else "float32"
    tp = TPConfig(mode=tp_mode, chunks=cais_chunks,
                  microbatches=tp_microbatches, planner=tp_planner,
                  sequence_parallel=True)
    return Runtime(compute_dtype="bfloat16", param_dtype=param_dtype,
                   tp=tp, remat=True)


def _dim_ok(shape, i, mesh, axis) -> bool:
    return sharding.axis_size(mesh, axis) > 1 and \
        shape[i] % sharding.axis_size(mesh, axis) == 0


def _axsize(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= sharding.axis_size(mesh, a)
        return n
    return sharding.axis_size(mesh, entry)


def sanitize_spec(mesh: Mesh, spec_entries, shape) -> P:
    """Drop spec axes that don't divide their dim (explicit in_shardings
    demand exact divisibility — e.g. batch=1 long-context decode replicates
    over the data axes; odd vocabs replicate over model)."""
    out = []
    for i, e in enumerate(spec_entries):
        if e is None or i >= len(shape):
            out.append(None)
            continue
        size = _axsize(mesh, e)
        if size > 1 and shape[i] % size == 0:
            out.append(e)
        elif isinstance(e, (tuple, list)):
            # keep the divisible prefix of a composite axis (e.g. batch 128
            # over ("pod","data")=32 ok; batch 8 keeps just "data"... )
            kept = []
            n = 1
            for a in e:
                s = sharding.axis_size(mesh, a)
                if s > 1 and shape[i] % (n * s) == 0:
                    kept.append(a)
                    n *= s
            out.append(tuple(kept) if kept else None)
        else:
            out.append(None)
    return P(*out)


_STACK_KEYS = ("periods", "enc_blocks", "dec_blocks")


def param_pspec(path: Tuple[str, ...], shape, cfg: ArchConfig, mesh: Mesh,
                fsdp: bool) -> P:
    """TP/SP/EP placement for one parameter. Scan-stacked params ("periods",
    whisper's "enc_blocks"/"dec_blocks") carry a leading layer dim that stays
    replicated; rules apply to the trailing (per-layer) dims."""
    name = path[-1]
    lead = 1 if any(k in path for k in _STACK_KEYS) else 0
    base = shape[lead:]
    nd = len(base)
    in_moe = "ffn" in path and cfg.moe is not None and "dense" not in path
    tp = sharding.tp_size(mesh)
    tp_ax = sharding.tp_axes(mesh)  # "model" or ("tp_in", "tp_out")

    def fin(spec_list, fsdp_prefer=()):
        # explicit in_shardings demand exact divisibility: drop any axis
        # that does not divide its dim (e.g. odd vocabs stay replicated)
        for i, e in enumerate(spec_list):
            if e is not None and base[i] % _axsize(mesh, e) != 0:
                spec_list[i] = None
        if fsdp:
            for i in fsdp_prefer:
                if spec_list[i] is None and \
                        sharding.axis_size(mesh, D_AX) > 1 and \
                        base[i] % sharding.axis_size(mesh, D_AX) == 0:
                    spec_list[i] = D_AX
                    break
        return P(*([None] * lead + spec_list))

    if name == "embed":                       # (V, d)
        return fin([tp_ax, None], (1,))
    if name == "router":                      # (d, E) — replicated, f32
        return fin([None, None])
    if in_moe and nd == 3 and name in ("w_up", "w_gate", "w_down"):
        E = base[0]
        hid = 2 if name in ("w_up", "w_gate") else 1
        if isinstance(tp_ax, tuple):
            n_out = sharding.axis_size(mesh, sharding.TP_OUT_AXIS)
            if n_out > 1 and E % n_out == 0:
                # grouped EP (docs/topology.md): experts over the slow
                # tp_out axis only; tp_in's share is the expert hidden dim.
                # The graph-path backward mirrors this placement:
                # hier_grad_a2a_expert_ffn keeps expert-grad all-to-alls on
                # tp_out, and dw partials complete over tp_in only
                # (docs/training.md)
                spec = [sharding.TP_OUT_AXIS, None, None]
                spec[hid] = sharding.TP_IN_AXIS
                return fin(spec, (1, 2))
        elif tp > 1 and E % tp == 0:          # flat expert parallelism
            return fin([M_AX, None, None], (1, 2))
        # expert-TP: shard the ffn hidden dim instead
        spec = [None, None, None]
        spec[hid] = tp_ax
        return fin(spec, (2, 1) if hid == 1 else (1,))
    if name in _COL or (nd == 2 and name in ("w_up", "w_gate")):
        return fin([None, tp_ax], (0,))
    if name in _ROW or (nd == 2 and name == "w_down"):
        return fin([tp_ax, None], (1,))
    # everything else (norms, conv filters, gates, biases, ssm params,
    # mamba2's fused in-proj — see DESIGN.md §5 applicability) replicates
    return P(*([None] * lead + [None] * nd))


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
        for p in path)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shape,
                    fsdp: bool = False):
    def one(path, leaf):
        spec = param_pspec(_path_keys(path), leaf.shape, cfg, mesh, fsdp)
        return sharding.named_sharding(mesh, *spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def _zero_spec(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: shard one replicated dim of the optimizer state over data."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None and _dim_ok(shape, i, mesh, D_AX):
            entries[i] = D_AX
            return P(*entries)
    return P(*entries)


def state_shardings(cfg: ArchConfig, mesh: Mesh, state_shape, rt: Runtime,
                    fsdp: bool = False):
    """Shardings for the {"params", "opt", "step"} train-state pytree."""
    pspecs: Dict[Tuple[str, ...], P] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            state_shape["params"])[0]:
        pspecs[_path_keys(path)] = param_pspec(
            _path_keys(path), leaf.shape, cfg, mesh, fsdp)

    def opt_spec(path, leaf):
        keys = _path_keys(path)
        # adamw: ("m"|"v", *param_path); adafactor: (*param_path, "vr"|...)
        if keys[0] in ("m", "v"):
            base, kind = keys[1:], keys[0]
        else:
            base, kind = keys[:-1], keys[-1]
        spec = pspecs.get(base, P())
        entries = list(spec) + [None] * max(0, len(leaf.shape) - len(spec))
        if kind == "vr":
            entries = entries[:-1]
        elif kind == "vc":
            entries = entries[:-2] + entries[-1:]
        entries = entries[:len(leaf.shape)]
        spec = P(*entries)
        if rt.zero_sharding:
            spec = _zero_spec(spec, leaf.shape, mesh)
        return sharding.named_sharding(mesh, *spec)

    def param_sh(path, leaf):
        return sharding.named_sharding(mesh, *pspecs[_path_keys(path)])

    return {
        "params": jax.tree_util.tree_map_with_path(
            param_sh, state_shape["params"]),
        "opt": jax.tree_util.tree_map_with_path(opt_spec, state_shape["opt"]),
        "step": sharding.named_sharding(mesh),
    }


# ---------------------------------------------------------------------------
# Cache shardings (decode cells): batch→data axes, long axis→model
# ---------------------------------------------------------------------------


def _cache_leaf_spec(name: str, nd: int) -> P:
    if name in ("k", "v"):            # (..., B, S|W, H, dh)
        tail = (B_AX, M_AX, None, None)
    elif name == "kpos":              # (..., B, W)
        tail = (B_AX, M_AX)
    elif name in ("c_kv", "k_rope"):  # (..., B, S, r)
        tail = (B_AX, M_AX, None)
    elif name == "h" and nd >= 4:     # ssm state (..., B, heads, p, n)
        tail = (B_AX, None, None, M_AX)
    elif name == "h":                 # rg-lru state (..., B, width)
        tail = (B_AX, M_AX)
    elif name == "conv":              # (..., B, w-1, channels)
        tail = (B_AX, None, M_AX)
    else:
        tail = ()
    lead = (None,) * (nd - len(tail))
    return P(*(lead + tail))


def cache_shardings(mesh: Mesh, cache_shape, layout: str = "context"):
    tp_ax = sharding.tp_axes(mesh)

    def one(path, leaf):
        name = _path_keys(path)[-1]
        spec = _cache_leaf_spec(name, len(leaf.shape))
        if layout == "batch_only":   # drop the model-axis (context) sharding
            spec = P(*(None if e == M_AX else e for e in spec))
        else:                        # composite TP axes on 2D meshes
            spec = P(*(tp_ax if e == M_AX else e for e in spec))
        spec = sanitize_spec(mesh, tuple(spec), leaf.shape)
        return sharding.named_sharding(mesh, *spec)
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def pool_shardings(cfg: ArchConfig, mesh: Mesh, pools_shape):
    """Paged-KV pool placement for the serving path (docs/serving.md).
    Leaves are (NB, BS, Hkv, dh) — possibly with a leading stacked-layer
    dim — and are UNBATCHED shared state: heads shard over the model axis
    exactly when they divide it (mirroring ``models.transformer.pool_pspec``
    inside the graph path); GQA pools whose heads don't divide stay fully
    replicated (every device writes identical values)."""
    tp = sharding.tp_size(mesh)
    head = sharding.tp_axes(mesh) if tp > 1 and cfg.num_kv_heads % tp == 0 \
        else None

    def one(path, leaf):
        nd = len(leaf.shape)
        tail = (None, None, head, None) if nd >= 4 else (None,) * nd
        spec = (None,) * (nd - len(tail)) + tail
        spec = sanitize_spec(mesh, spec, leaf.shape)
        return sharding.named_sharding(mesh, *spec)
    return jax.tree_util.tree_map_with_path(one, pools_shape)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per (arch × shape)
# ---------------------------------------------------------------------------


def batch_struct(cfg: ArchConfig, shape: ShapeConfig,
                 rt: Runtime) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch structs (tokens shifted labels for train)."""
    b = shape.global_batch
    s = shape.seq_len
    if cfg.num_prefix_tokens:
        s = s - cfg.num_prefix_tokens     # image prefix occupies positions
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.is_enc_dec:
        out["src_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.max_source_len, cfg.d_model), jnp.float32)
    if cfg.num_prefix_tokens:
        out["patch_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_tokens, cfg.vision_width), jnp.float32)
    return out


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    rt: Runtime):
    structs = batch_struct(cfg, shape, rt)
    return {
        k: sharding.named_sharding(mesh, *sanitize_spec(
            mesh, (B_AX,) + (None,) * (len(v.shape) - 1), v.shape))
        for k, v in structs.items()
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime,
                model=None) -> Dict[str, Any]:
    """All inputs of the cell's step as ShapeDtypeStructs.

    train:   {"state", "batch"}
    prefill: {"params", "batch"}
    decode:  {"params", "token", "caches", "idx"}
    """
    model = model or build_model(cfg, rt)
    if shape.kind == "train":
        from repro.optim import constant_schedule, make_optimizer
        from repro.train.step import init_state
        opt = make_optimizer(cfg.optimizer, constant_schedule(1e-4))
        state = jax.eval_shape(
            lambda: init_state(model, opt, jax.random.key(0)))
        return {"state": state, "batch": batch_struct(cfg, shape, rt)}

    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_struct(cfg, shape, rt)}

    # decode: one new token against a seq_len KV cache
    b = shape.global_batch
    caches = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len))
    return {
        "params": params,
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": caches,
        "idx": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
