"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests and benches must see 1 device; only
the dry-run forces 512 virtual hosts)."""
from __future__ import annotations

from repro.sharding import DATA_AXIS, MODEL_AXIS, POD_AXIS, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data × model). Multi-pod: 2 pods =
    512 chips with cross-pod DP on the `pod` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod \
        else (DATA_AXIS, MODEL_AXIS)
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for the 8-virtual-device test suite."""
    return make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))
