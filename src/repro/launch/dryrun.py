import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init). 512 virtual host devices host the production meshes: 16×16 single
# pod and 2×16×16 multi-pod. This module is the ONLY place that sets it.

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import sharding                              # noqa: E402
from repro.configs import (SHAPES, SHAPES_BY_NAME, get_arch,  # noqa: E402
                           list_archs, shape_applicable)
from repro.launch import specs as S                     # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.roofline.hlo_analyzer import analyze      # noqa: E402
from repro.roofline.hlo_costs import (collective_bytes,  # noqa: E402
                                      cost_summary, memory_summary,
                                      roofline_terms)
from repro.runtime import Runtime                       # noqa: E402


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               tp_mode: str = "auto", cais_chunks: "int | None" = None,
               rt_overrides: dict = None):
    """Lower + compile one (arch × shape × mesh) cell. Returns (lowered,
    compiled, meta). ``rt_overrides`` patches Runtime fields (the §Perf
    hillclimb uses this to try remat/SP/chunking variants)."""
    import dataclasses

    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = S.runtime_for(cfg, tp_mode=tp_mode, cais_chunks=cais_chunks)
    if rt_overrides:
        from repro.runtime import TPConfig
        ov = dict(rt_overrides)
        tp_fields = {f.name for f in dataclasses.fields(TPConfig)}
        tp_ov = {k: ov.pop(k) for k in list(ov) if k in tp_fields}
        tp = dataclasses.replace(rt.tp, **tp_ov) if tp_ov else rt.tp
        rt = dataclasses.replace(rt, tp=tp, **ov)
    model = build_model(cfg, rt)
    ins = S.input_specs(cfg, shape, rt, model=model)

    with sharding.use_mesh(mesh):
        if shape.kind == "train":
            from repro.optim import constant_schedule, make_optimizer
            from repro.train.step import make_train_step
            opt = make_optimizer(cfg.optimizer, constant_schedule(1e-4))
            # gradient accumulation bounds activation temps for the huge
            # MoE archs (per-device batch stays >= 1 on both meshes)
            micro = 4 if cfg.param_count() > 4e10 else 1
            step = make_train_step(model, opt, rt, microbatches=micro)
            st_sh = S.state_shardings(cfg, mesh, ins["state"], rt,
                                      fsdp=rt.param_dtype == "bfloat16")
            b_sh = S.batch_shardings(cfg, shape, mesh, rt)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(ins["state"], ins["batch"])
        elif shape.kind == "prefill":
            p_sh = S.param_shardings(cfg, mesh, ins["params"],
                                     fsdp=rt.param_dtype == "bfloat16")
            b_sh = S.batch_shardings(cfg, shape, mesh, rt)
            fn = lambda p, b: model.prefill(p, b, s_max=shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(ins["params"], ins["batch"])
        else:  # decode
            p_sh = S.param_shardings(cfg, mesh, ins["params"],
                                     fsdp=rt.param_dtype == "bfloat16")
            c_sh = S.cache_shardings(mesh, ins["caches"], rt.cache_layout)
            t_sh = sharding.named_sharding(mesh, *S.sanitize_spec(
                mesh, (S.B_AX, None), ins["token"].shape))
            i_sh = sharding.named_sharding(mesh, *S.sanitize_spec(
                mesh, (S.B_AX,), ins["idx"].shape))
            jitted = jax.jit(model.decode_step,
                             in_shardings=(p_sh, t_sh, c_sh, i_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(ins["params"], ins["token"],
                                   ins["caches"], ins["idx"])

        compiled = lowered.compile()

    return lowered, compiled, {"mesh": "multi" if multi_pod else "single",
                               "tp_mode": tp_mode}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             tp_mode: str = "auto", cais_chunks: "int | None" = None,
             verbose: bool = True, rt_overrides: dict = None) -> dict:
    t0 = time.monotonic()
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": n_chips, "tp_mode": tp_mode,
           "rt_overrides": rt_overrides or {}}
    try:
        lowered, compiled, meta = lower_cell(arch_name, shape_name,
                                             multi_pod, tp_mode, cais_chunks,
                                             rt_overrides)
        if lowered is None:
            rec["status"] = "skipped"
            rec["reason"] = meta["skipped"]
            return rec
        rec["status"] = "ok"
        hlo = compiled.as_text()
        rec["cost"] = cost_summary(compiled)       # raw (scan bodies ×1)
        rec["memory"] = memory_summary(compiled)
        rec["collectives"] = collective_bytes(hlo)  # raw, unmultiplied
        # while-aware analysis: scan bodies × trip count (the real costs)
        rec["hlo_analysis"] = analyze(hlo)
        # collective term uses per-direction wire bytes: bidirectional
        # permute schedules occupy both full-duplex ICI directions at once
        roof = roofline_terms(rec["hlo_analysis"]["flops"],
                              rec["hlo_analysis"]["bytes"],
                              rec["hlo_analysis"].get(
                                  "collective_wire",
                                  rec["hlo_analysis"]["collective_total"]))
        rec["roofline"] = roof.as_dict()
        cfg = get_arch(arch_name)
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        if verbose:
            print(f"  memory_analysis: {compiled.memory_analysis()}")
            print(f"  cost_analysis: flops={rec['cost']['flops']:.3e} "
                  f"bytes={rec['cost']['bytes']:.3e}")
            print(f"  collective bytes/device: {rec['collectives']}")
    except Exception as e:  # a failure here is a bug in our system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["compile_s"] = round(time.monotonic() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    from repro.core.backends import available_backends
    ap.add_argument("--tp-mode", default="auto",
                    choices=available_backends())
    ap.add_argument("--cais-chunks", type=int, default=None,
                    help="static ring-chunk override; default lets the cais "
                         "backend plan per collective")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}.{shape}.{'multi' if multi else 'single'}" + \
                    (f".{args.tp_mode}" if args.tp_mode != "auto" else "")
                print(f"=== {tag} ===", flush=True)
                rec = run_cell(arch, shape, multi, args.tp_mode,
                               args.cais_chunks)
                print(f"  -> {rec['status']} ({rec.get('compile_s', 0)}s)"
                      + (f" {rec.get('reason', rec.get('error', ''))}"
                         if rec["status"] != "ok" else ""), flush=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "error":
                    failures += 1
    print(f"dry-run complete; {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
