"""Production serving launcher: batched requests through the Engine.

    python -m repro.launch.serve --arch gemma3-1b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch import specs as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    rt = S.runtime_for(cfg)
    if args.smoke:
        cfg = cfg.smoke()
        rt = dataclasses.replace(rt, compute_dtype="float32",
                                  remat=False)
    mesh = {"none": None, "debug": make_debug_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]
    mesh = mesh() if callable(mesh) else mesh

    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    extras = {}
    if cfg.is_enc_dec:
        extras["src_embed"] = np.random.default_rng(0).standard_normal(
            (args.requests, cfg.encoder.max_source_len, cfg.d_model)
        ).astype(np.float32)
    if cfg.num_prefix_tokens:
        extras["patch_embed"] = np.random.default_rng(0).standard_normal(
            (args.requests, cfg.num_prefix_tokens, cfg.vision_width)
        ).astype(np.float32)

    eng = Engine(model, params, cfg, rt,
                 ServeConfig(max_batch=args.requests,
                             s_max=args.prompt_len + args.max_new
                             + cfg.num_prefix_tokens),
                 mesh=mesh, extras=extras)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs)
    for r in reqs:
        print(f"request {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
