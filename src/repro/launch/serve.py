"""Production serving launcher: load-generated requests through the paged
continuous-batching Engine (docs/serving.md).

    python -m repro.launch.serve --smoke
    python -m repro.launch.serve --arch gemma3-1b --load poisson --rate 16
    python -m repro.launch.serve --arch deepseek-7b --engine dense \
        --load burst --report reports/serve_latency.json

``--load none`` keeps the old fixed-prompt batch; ``poisson``/``burst``
drive the seeded arrival processes from :mod:`repro.serve.loadgen` and
print the p50/p99 TTFT / per-token latency / tokens-per-sec-per-device
report (optionally written as a JSON artifact via ``--report``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch import specs as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import build_model
from repro.serve import (DenseEngine, Engine, LoadSpec, Request, ServeConfig,
                         format_report, generate)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    ap.add_argument("--engine", default="paged", choices=["paged", "dense"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--load", default="none",
                    choices=["none", "poisson", "burst"])
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--burst-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", default="")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    rt = S.runtime_for(cfg)
    if args.smoke:
        cfg = cfg.smoke()
        rt = dataclasses.replace(rt, compute_dtype="float32",
                                  remat=False)
        args.requests = min(args.requests, 4)
        args.prompt_len = min(args.prompt_len, 8)
        args.max_new = min(args.max_new, 4)
    mesh = {"none": None, "debug": make_debug_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]
    mesh = mesh() if callable(mesh) else mesh

    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    extras = {}
    if cfg.is_enc_dec:
        extras["src_embed"] = np.random.default_rng(0).standard_normal(
            (args.requests, cfg.encoder.max_source_len, cfg.d_model)
        ).astype(np.float32)
    if cfg.num_prefix_tokens:
        extras["patch_embed"] = np.random.default_rng(0).standard_normal(
            (args.requests, cfg.num_prefix_tokens, cfg.vision_width)
        ).astype(np.float32)

    sc = ServeConfig(max_batch=args.requests,
                     s_max=args.prompt_len + args.max_new
                     + cfg.num_prefix_tokens)
    cls = Engine if args.engine == "paged" else DenseEngine
    eng = cls(model, params, cfg, rt, sc, mesh=mesh, extras=extras)

    if args.load == "none":
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            args.prompt_len).astype(np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]
    else:
        spec = LoadSpec(kind=args.load, num_requests=args.requests,
                        rate=args.rate, burst_size=args.burst_size,
                        prompt_len_min=max(args.prompt_len // 2, 1),
                        prompt_len_max=args.prompt_len,
                        max_new_tokens=args.max_new, seed=args.seed)
        reqs = generate(spec, cfg.vocab_size)

    eng.run(reqs, key=args.seed)
    for r in reqs:
        print(f"request {r.rid}: {r.out_tokens}")
    if eng.last_report:
        print(f"[{args.engine}] {format_report(eng.last_report)}")
        if args.report:
            os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
            with open(args.report, "w") as fh:
                json.dump(eng.last_report, fh, indent=1, sort_keys=True)
            print(f"latency report -> {args.report}")
    if args.smoke:
        assert all(r.done and len(r.out_tokens) == args.max_new
                   for r in reqs), "serve smoke: incomplete requests"
        print("serve smoke OK "
              f"(arch={args.arch} engine={args.engine} paged="
              f"{getattr(eng, '_paged', False)})")


if __name__ == "__main__":
    main()
