"""Production training launcher.

    python -m repro.launch.train --arch deepseek-7b --shape train_4k \
        --mesh single --tp-mode cais --steps 100 --ckpt-dir /ckpts/run1

On a real pod this process runs per-host under the TPU runtime and the mesh
maps onto physical chips; on this box it drives whatever devices exist (use
--smoke for a reduced config on CPU). All state is sharded per
launch/specs.py; restart is automatic from --ckpt-dir (deterministic resume,
see train/trainer.py)."""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import sharding
from repro.configs import SHAPES_BY_NAME, ShapeConfig, get_arch
from repro.core.backends import available_backends
from repro.data.pipeline import DataConfig
from repro.launch import specs as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.train import Trainer, TrainerConfig
from repro.train.step import init_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    ap.add_argument("--tp-mode", default="auto",
                    choices=available_backends())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        shape = ShapeConfig("smoke_train", 128, 4, "train")
        rt = S.runtime_for(cfg, tp_mode=args.tp_mode)
        rt = dataclasses.replace(rt, compute_dtype="float32",
                                  remat=False, loss_chunk=64)
    else:
        shape = SHAPES_BY_NAME[args.shape]
        rt = S.runtime_for(cfg, tp_mode=args.tp_mode)

    mesh = {"none": None, "debug": make_debug_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]
    mesh = mesh() if callable(mesh) else mesh

    model = build_model(cfg, rt)
    opt = make_optimizer(cfg.optimizer,
                         cosine_schedule(args.lr, args.warmup, args.steps))
    tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_every=10,
                       microbatches=args.microbatches)
    trainer = Trainer(model, opt, cfg, shape, rt, tc, DataConfig(args.seed),
                      mesh=mesh)

    if mesh is not None:
        # shard the fresh/restored state onto the mesh before stepping
        with sharding.use_mesh(mesh):
            state = trainer.restore_or_init(args.seed)
            shapes = jax.eval_shape(lambda: state)
            sh = S.state_shardings(cfg, mesh, shapes, rt)
            state = jax.device_put(state, sh)
            trainer.run(state)
    else:
        trainer.run()


if __name__ == "__main__":
    main()
