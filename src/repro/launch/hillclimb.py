"""§Perf hillclimbing driver: run Runtime variants of a dry-run cell and
log hypothesis → change → before/after roofline terms (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek-7b:train_4k

Each variant is one (hypothesis, Runtime patch); the dominant term of the
baseline decides which levers are enumerated (DESIGN.md §4 + the assignment's
per-iteration methodology)."""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

# (name, hypothesis, tp_mode, cais_chunks, rt_overrides)
VARIANTS = {
    "baseline": ("paper-faithful SP-TP with monolithic (NVLS-style) "
                 "collectives scheduled by XLA", "auto", 8, {}),
    "barrier": ("explicit barrier collectives (strict NVLS phase structure; "
                "expect ≥ baseline collective exposure)", "barrier", 8, {}),
    "cais8": ("CAIS decomposed bidirectional ring schedules, 8 chunks: "
              "collective bytes move to collective-permute and overlap "
              "with partial GEMMs", "cais", 8, {}),
    "cais-plan": ("compute-aware chunking: the cais backend picks "
                  "num_chunks per collective from payload bytes and ring "
                  "size (coordination.plan) instead of one static value",
                  "cais", None, {}),
    "cais2": ("coarser chunks (2): fewer permutes, bigger staging buffer — "
              "latency ↓, overlap granularity ↓", "cais", 2, {}),
    "cais16": ("finer chunks (16): finer overlap, more per-hop latency",
               "cais", 16, {}),
    "cais8-uni": ("unidirectional rings (CAIS-Base analogue): one ICI "
                  "direction idles — collective term should ~2×",
                  "cais", 8, {"cais_bidirectional": False}),
    "no-remat": ("disable activation checkpointing: recompute flops "
                 "disappear (compute term ↓ ~25%), memory residency ↑",
                 "auto", 8, {"remat": False}),
    "no-sp": ("disable sequence parallelism: activations replicated on "
              "model axis between blocks — collective pattern shifts "
              "AG/RS → AR", "auto", 8, {"sequence_parallel": False}),
    # ---- decode-cell levers ----
    "cache-repl": ("replicate the KV cache over the TP axis instead of "
                   "context-parallel sharding: memory term should blow up "
                   "~tp x on the cache-read side (negative control)",
                   "auto", 8, {"cache_layout": "batch_only"}),
    "f32-compute": ("f32 activations/caches instead of bf16: memory term "
                    "x2 (confirms the dtype lever)", "auto", 8,
                    {"compute_dtype": "float32"}),
    # ---- stacked winners ----
    "cais2-noremat": ("stack the two confirmed wins: coarse-chunk CAIS "
                      "rings + no recompute (activations fit at 4k)",
                      "cais", 2, {"remat": False}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch:shape, e.g. deepseek-7b:train_4k")
    ap.add_argument("--variants", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="reports/hillclimb")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    names = list(VARIANTS) if args.variants == "all" \
        else args.variants.split(",")
    os.makedirs(args.out, exist_ok=True)

    results = {}
    for name in names:
        hyp, mode, chunks, rto = VARIANTS[name]
        print(f"=== {arch}:{shape} [{name}] ===\n  hypothesis: {hyp}",
              flush=True)
        rec = run_cell(arch, shape, args.mesh == "multi", mode, chunks,
                       verbose=False, rt_overrides=rto)
        rec["variant"] = name
        rec["hypothesis"] = hyp
        results[name] = rec
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"dominant={r['dominant']} ({rec['compile_s']}s compile)",
                  flush=True)
            ca = rec["hlo_analysis"]
            print(f"  coll mix: " + " ".join(
                f"{k.split('_')[1]}={v:.2e}" for k, v in ca.items()
                if k.startswith("coll_") and v > 0), flush=True)
        else:
            print(f"  -> {rec['status']}: {rec.get('error', '')[:200]}",
                  flush=True)
        with open(os.path.join(args.out, f"{arch}.{shape}.{name}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
