"""§Perf hillclimbing driver: run Runtime variants of a dry-run cell and
log hypothesis → change → before/after roofline terms (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek-7b:train_4k

Each variant is one (hypothesis, Runtime patch); the dominant term of the
baseline decides which levers are enumerated (DESIGN.md §4 + the assignment's
per-iteration methodology). After the sweep a ranking table (ordered by the
dominant roofline bound) is printed and written to ``<out>/summary.json``.

``--auto`` replaces the hand-written VARIANTS ladder with the repro.plan
search: it sweeps the (backend × num_chunks × microbatch-split) grid of a
2-block dense period proxy of the cell, ranks the grid by simulated makespan,
then dry-runs only the ``--top`` best so the simulated ranking can be checked
against the measured roofline bounds (docs/planner.md)."""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

# (name, hypothesis, tp_mode, cais_chunks, rt_overrides)
VARIANTS = {
    "baseline": ("paper-faithful SP-TP with monolithic (NVLS-style) "
                 "collectives scheduled by XLA", "auto", 8, {}),
    "barrier": ("explicit barrier collectives (strict NVLS phase structure; "
                "expect ≥ baseline collective exposure)", "barrier", 8, {}),
    "cais8": ("CAIS decomposed bidirectional ring schedules, 8 chunks: "
              "collective bytes move to collective-permute and overlap "
              "with partial GEMMs", "cais", 8, {}),
    "cais-plan": ("compute-aware chunking: the cais backend picks "
                  "num_chunks per collective from payload bytes and ring "
                  "size (coordination.plan) instead of one static value",
                  "cais", None, {}),
    "cais2": ("coarser chunks (2): fewer permutes, bigger staging buffer — "
              "latency ↓, overlap granularity ↓", "cais", 2, {}),
    "cais16": ("finer chunks (16): finer overlap, more per-hop latency",
               "cais", 16, {}),
    "cais8-uni": ("unidirectional rings (CAIS-Base analogue): one ICI "
                  "direction idles — collective term should ~2×",
                  "cais", 8, {"bidirectional": False}),
    "no-remat": ("disable activation checkpointing: recompute flops "
                 "disappear (compute term ↓ ~25%), memory residency ↑",
                 "auto", 8, {"remat": False}),
    "no-sp": ("disable sequence parallelism: activations replicated on "
              "model axis between blocks — collective pattern shifts "
              "AG/RS → AR", "auto", 8, {"sequence_parallel": False}),
    # ---- decode-cell levers ----
    "cache-repl": ("replicate the KV cache over the TP axis instead of "
                   "context-parallel sharding: memory term should blow up "
                   "~tp x on the cache-read side (negative control)",
                   "auto", 8, {"cache_layout": "batch_only"}),
    "f32-compute": ("f32 activations/caches instead of bf16: memory term "
                    "x2 (confirms the dtype lever)", "auto", 8,
                    {"compute_dtype": "float32"}),
    # ---- stacked winners ----
    "cais2-noremat": ("stack the two confirmed wins: coarse-chunk CAIS "
                      "rings + no recompute (activations fit at 4k)",
                      "cais", 2, {"remat": False}),
}

# production model-axis degree (launch.mesh: 16×16 / 2×16×16, model=16)
_TP = 16


def _dense_weight_shapes(d: int, d_ff: int, blocks: int,
                         has_gate: bool) -> dict:
    """Weight-key → global shape map for ``dense_period_graph`` blocks
    (mirrors ``tp._dense_block_nodes`` naming)."""
    out = {}
    for i in range(blocks):
        p = f"b{i}."
        out.update({p + "scale1": (d,), p + "scale2": (d,),
                    p + "wq": (d, d), p + "wk": (d, d), p + "wv": (d, d),
                    p + "wo": (d, d), p + "w_up": (d, d_ff),
                    p + "w_down": (d_ff, d)})
        if has_gate:
            out[p + "w_gate"] = (d, d_ff)
    return out


def auto_variants(arch_name: str, shape_name: str, multi_pod: bool,
                  top_k: int = 3):
    """Planner-driven variant enumeration: sweep the (backend × chunks ×
    microbatch) grid of the cell's 2-block dense period proxy by simulated
    makespan, return the ``top_k`` grid points as hillclimb variants plus
    the full simulated ranking ``[{variant, makespan_s, ...}, ...]``."""
    from repro import plan as plan_mod
    from repro.configs import SHAPES_BY_NAME, get_arch
    from repro.core import dataflow as df, tp as tp_mod
    from repro.hw import V5E

    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    chips = 512 if multi_pod else 256
    dp = max(chips // _TP, 1)
    b_loc = max(shape.global_batch // dp, 1)
    seq = 1 if shape.kind == "decode" else shape.seq_len
    has_gate = cfg.act != "gelu_mlp"

    core = lambda q, k, v: q  # opaque for the cost model   # noqa: E731
    base = tp_mod.dense_period_graph([core] * 2, has_gate=has_gate,
                                     act=cfg.act)
    weights = _dense_weight_shapes(cfg.d_model, cfg.d_ff, blocks=2,
                                   has_gate=has_gate)
    fabric = plan_mod.fabric_from_hw(V5E, _TP)

    grid = []
    for backend in ("barrier", "cais"):
        chunk_grid = (None,) if backend == "barrier" else (None, 2, 8, 16)
        for mb in (1, 2, 4):
            if b_loc % mb or mb > b_loc:
                continue
            merged = base if mb == 1 else df.merge_graphs(
                [base] * mb, share_weights=True)
            g2 = df.fuse_sublayer_chain(df.fuse_shared_gather(
                df.fuse_compute_aware(merged)))
            values = plan_mod.microbatch_value_shapes(
                (b_loc, seq, cfg.d_model), mb)
            for chunks in chunk_grid:
                p = plan_mod.search_pairing(
                    g2, fabric=fabric, backend=backend,
                    value_shapes=values, weight_shapes=weights,
                    dtype_bytes=2, num_microbatches=mb,
                    chunk_candidates=(chunks,))
                cname = "cplan" if chunks is None else f"c{chunks}"
                grid.append({"variant": f"{backend}-{cname}-mb{mb}",
                             "backend": backend, "chunks": chunks,
                             "microbatches": mb,
                             "makespan_s": p.makespan})
    grid.sort(key=lambda r: r["makespan_s"])
    for rank, row in enumerate(grid, 1):
        row["sim_rank"] = rank

    variants = {}
    for row in grid[:top_k]:
        hyp = (f"planner pick #{row['sim_rank']}: simulated makespan "
               f"{row['makespan_s']:.3e}s for backend={row['backend']} "
               f"chunks={row['chunks']} microbatches={row['microbatches']} "
               f"on the 2-block dense period proxy")
        variants[row["variant"]] = (hyp, row["backend"], row["chunks"],
                                    {"microbatches": row["microbatches"]})
    return variants, grid


def summarize(results: dict, cell: str, mesh: str, out_dir: str,
              sim_ranking=None) -> dict:
    """Rank ok variants by their dominant roofline bound, print the table,
    name the winner, and persist everything to ``<out_dir>/summary.json``."""
    ok, failed = [], []
    for name, rec in results.items():
        if rec["status"] != "ok":
            failed.append({"variant": name, "status": rec["status"]})
            continue
        r = rec["roofline"]
        ok.append({"variant": name, "status": "ok",
                   "dominant": r["dominant"],
                   "bound_s": max(r["compute_s"], r["memory_s"],
                                  r["collective_s"]),
                   "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                   "collective_s": r["collective_s"],
                   "hypothesis": rec.get("hypothesis", "")})
    ok.sort(key=lambda r: r["bound_s"])
    winner = ok[0]["variant"] if ok else None
    summary = {"cell": cell, "mesh": mesh, "winner": winner,
               "ranked": ok + failed}
    if sim_ranking is not None:
        summary["simulated_ranking"] = sim_ranking

    print("\n=== ranking (dominant roofline bound, best first) ===")
    print(f"{'rank':>4} {'variant':<18} {'bound_s':>10} {'dominant':<10} "
          f"{'compute':>10} {'memory':>10} {'collective':>10}")
    for i, r in enumerate(ok, 1):
        print(f"{i:>4} {r['variant']:<18} {r['bound_s']:>10.3e} "
              f"{r['dominant']:<10} {r['compute_s']:>10.3e} "
              f"{r['memory_s']:>10.3e} {r['collective_s']:>10.3e}")
    for r in failed:
        print(f"   - {r['variant']:<18} {r['status']}")
    if winner:
        print(f"winner: {winner} ({ok[0]['dominant']}-bound, "
              f"{ok[0]['bound_s']:.3e}s)")

    if sim_ranking is not None and ok:
        measured_rank = {r["variant"]: i for i, r in enumerate(ok, 1)}
        print("\n=== simulated vs measured (dry-run subset) ===")
        print(f"{'variant':<18} {'sim_rank':>8} {'sim_s':>10} "
              f"{'meas_rank':>9} {'bound_s':>10}")
        for row in sim_ranking:
            if row["variant"] not in measured_rank:
                continue
            m = next(r for r in ok if r["variant"] == row["variant"])
            print(f"{row['variant']:<18} {row['sim_rank']:>8} "
                  f"{row['makespan_s']:>10.3e} "
                  f"{measured_rank[row['variant']]:>9} "
                  f"{m['bound_s']:>10.3e}")

    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(f"summary -> {os.path.join(out_dir, 'summary.json')}")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch:shape, e.g. deepseek-7b:train_4k")
    ap.add_argument("--variants", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="reports/hillclimb")
    ap.add_argument("--auto", action="store_true",
                    help="enumerate variants with the repro.plan search "
                         "instead of the hand-written VARIANTS ladder")
    ap.add_argument("--top", type=int, default=3,
                    help="--auto: dry-run this many best simulated points")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)

    sim_ranking = None
    if args.auto:
        variants, sim_ranking = auto_variants(arch, shape,
                                              args.mesh == "multi", args.top)
        print(f"=== planner grid: {len(sim_ranking)} points, "
              f"dry-running top {len(variants)} ===")
        for row in sim_ranking:
            print(f"  #{row['sim_rank']:<3} {row['variant']:<18} "
                  f"simulated={row['makespan_s']:.3e}s", flush=True)
    else:
        names = list(VARIANTS) if args.variants == "all" \
            else args.variants.split(",")
        variants = {n: VARIANTS[n] for n in names}

    results = {}
    for name, (hyp, mode, chunks, rto) in variants.items():
        print(f"=== {arch}:{shape} [{name}] ===\n  hypothesis: {hyp}",
              flush=True)
        rec = run_cell(arch, shape, args.mesh == "multi", mode, chunks,
                       verbose=False, rt_overrides=rto)
        rec["variant"] = name
        rec["hypothesis"] = hyp
        results[name] = rec
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"dominant={r['dominant']} ({rec['compile_s']}s compile)",
                  flush=True)
            ca = rec["hlo_analysis"]
            print(f"  coll mix: " + " ".join(
                f"{k.split('_')[1]}={v:.2e}" for k, v in ca.items()
                if k.startswith("coll_") and v > 0), flush=True)
        else:
            print(f"  -> {rec['status']}: {rec.get('error', '')[:200]}",
                  flush=True)
        with open(os.path.join(args.out, f"{arch}.{shape}.{name}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)

    summarize(results, args.cell, args.mesh, args.out, sim_ranking)


if __name__ == "__main__":
    main()
