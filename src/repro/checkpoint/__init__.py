from repro.checkpoint.store import AsyncSaver, latest_step, restore, save

__all__ = ["AsyncSaver", "save", "restore", "latest_step"]
