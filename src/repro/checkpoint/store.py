"""Sharded checkpointing: save/restore the train state with a manifest, an
async writer, integrity hashes, and *elastic resharding* (restore onto a
different mesh than the one that wrote the checkpoint).

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json      — step, arch, flat-key index, shapes/dtypes, crc
        arrays.npz         — flat {index: array} (host-gathered)
    ckpt_dir/LATEST        — atomic pointer file

Arrays are gathered to host (`jax.device_get`) before writing — on a real
multi-host pod each process writes only its addressable shards; here the
single process owns everything. Restore `device_put`s against the *target*
mesh's shardings, so a checkpoint written on (16,16) restores onto (2,16,16)
or a CPU smoke mesh unchanged (elastic scaling).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import zlib
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save(ckpt_dir: str, state, step: int, extra: Optional[dict] = None,
         _sync: bool = True) -> str:
    """Write a checkpoint; returns its directory. Atomic via tmp+rename."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{name}_")

    host = {k: np.asarray(jax.device_get(v)) for k, v in
            _flatten(state).items()}
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **{str(i): a for i, a in enumerate(host.values())})
    manifest = {
        "step": int(step),
        "keys": list(host.keys()),
        "shapes": [list(a.shape) for a in host.values()],
        "dtypes": [str(a.dtype) for a in host.values()],
        "crc32": [int(zlib.crc32(a.tobytes())) for a in host.values()],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncSaver:
    """Background-thread checkpoint writer; never blocks the step loop for
    longer than the host-gather. `wait()` before process exit."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None

    def save(self, state, step: int, extra: Optional[dict] = None):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            save(self.ckpt_dir, host_state, step, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[str, np.ndarray], Any]] = None,
            verify: bool = True):
    """Restore into the structure of `template`. `sharding_fn(key, array)`
    returns the target sharding (or None) per leaf — pass the new mesh's
    shardings to reshard elastically."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = [z[str(i)] for i in range(len(manifest["keys"]))]
    if verify:
        for a, crc in zip(arrays, manifest["crc32"]):
            if int(zlib.crc32(a.tobytes())) != crc:
                raise IOError("checkpoint corruption detected (crc mismatch)")
    flat = {}
    for key, arr in zip(manifest["keys"], arrays):
        if sharding_fn is not None:
            sh = sharding_fn(key, arr)
            flat[key] = jax.device_put(arr, sh) if sh is not None else \
                jax.device_put(arr)
        else:
            flat[key] = jax.device_put(arr)
    return _unflatten_into(template, flat), manifest
