from repro.data.pipeline import DataConfig, iterate, make_batch

__all__ = ["DataConfig", "iterate", "make_batch"]
