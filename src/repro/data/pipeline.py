"""Deterministic synthetic token pipeline.

Batches are generated from a PRNG keyed on (seed, step) — any step's batch is
reproducible without replaying the stream, which makes checkpoint-restart
deterministic (the trainer stores only the step). Per-host sharding: each
process materializes only its addressable slice of the global batch
(`host_slice`), matching multi-host TPU input pipelines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # synthetic "documents": zipf-ish token marginals + shift labels
    zipf_alpha: float = 1.1


def _tokens_for_step(cfg: ArchConfig, batch: int, seq: int, seed: int,
                     step: int, zipf_alpha: float) -> np.ndarray:
    rng = np.random.default_rng(np.random.PCG64(seed * 1_000_003 + step))
    # zipf marginal bounded to vocab
    ranks = rng.zipf(zipf_alpha, size=(batch, seq + 1)).astype(np.int64)
    return (ranks % cfg.vocab_size).astype(np.int32)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, step: int,
               data: Optional[DataConfig] = None,
               host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
    """One global (or host-sliced) training batch for (arch, shape, step)."""
    # default constructed per call: a def-time default would be one shared
    # instance across every caller (same pattern as the old Engine bug —
    # harmless only while the config stays frozen)
    data = data if data is not None else DataConfig()
    b, s = shape.global_batch, shape.seq_len
    toks = _tokens_for_step(cfg, b, s, data.seed, step, data.zipf_alpha)
    if host_slice is not None:
        toks = toks[host_slice]
    batch = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
    }
    n = toks.shape[0]
    if cfg.is_enc_dec:
        rng = np.random.default_rng(np.random.PCG64(data.seed ^ 0xE0C + step))
        batch["src_embed"] = rng.standard_normal(
            (n, cfg.encoder.max_source_len, cfg.d_model)).astype(np.float32)
    if cfg.num_prefix_tokens:
        rng = np.random.default_rng(np.random.PCG64(data.seed ^ 0x1A6 + step))
        batch["patch_embed"] = rng.standard_normal(
            (n, cfg.num_prefix_tokens, cfg.vision_width)).astype(np.float32)
    return batch


def iterate(cfg: ArchConfig, shape: ShapeConfig, start_step: int = 0,
            data: Optional[DataConfig] = None,
            host_slice: Optional[slice] = None) -> Iterator[Dict[str, np.ndarray]]:
    data = data if data is not None else DataConfig()
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, data, host_slice)
        step += 1
