"""Runtime (non-architecture) knobs: dtypes, parallelism config, remat.

Separated from ArchConfig so the same architecture can be lowered with
different distribution/precision strategies (baseline vs CAIS vs hillclimbed).

Tensor-parallel knobs live on ONE nested config — :class:`TPConfig`, exposed
as ``Runtime.tp`` — instead of the historical flat ``tp_*``/``cais_*`` field
sprawl. The old flat names (``tp_mode``, ``cais_chunks``,
``cais_bidirectional``, ``tp_microbatches``, ``tp_planner``,
``sequence_parallel``) are still accepted as constructor keywords and
readable as attributes, but both directions warn ``DeprecationWarning`` and
forward to ``Runtime.tp``; the single construction path to an execution
context is ``TPConfig → repro.core.tp.TPContext.from_config``.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional, Union

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass(frozen=True)
class TPConfig:
    """Every tensor-parallel decision in one place (``Runtime.tp``).

    ``mode`` is any :mod:`repro.core.backends` registry name; ``chunks=None``
    lets the cais backend plan the ring chunking per collective from payload
    bytes (:func:`repro.core.coordination.plan`); ``microbatches`` is the
    period-graph batch split (int, or ``"auto"`` via ``plan_microbatches``;
    ``"auto"`` never splits MoE periods — their aux loss is a per-batch
    statistic the split would change, so that trade-off needs an explicit
    integer opt-in); ``planner`` drives pass 3 of the graph optimizer
    (``"greedy"`` or ``"perfsim"``); ``graph_backward`` routes period
    training gradients — dense, MoE (including the routed-expert all-to-all
    and the aux-loss statistic), and the replicated-activation
    decode/ragged layout down to S=1 — through the graph-built custom VJP
    (``docs/training.md``) instead of JAX autodiff of the executed forward
    graph — the backward then lowers through the same ``optimize() →
    execute()`` path and pass 3 can pair forward and backward collectives.
    Periods whose graphs carry an op with no declared adjoint fall back to
    autodiff with a once-per-op-set ``UserWarning``."""

    mode: str = "auto"                  # any repro.core.backends name
    sequence_parallel: bool = True      # SP-TP layout (paper's primary)
    chunks: Optional[int] = None        # ring chunks; None = planner-chosen
    bidirectional: bool = True          # asymmetric/bidirectional overlap
    microbatches: Union[int, str] = 1   # period-graph batch split
    planner: str = "greedy"             # pass-3 planner: greedy | perfsim
    graph_backward: bool = True         # period grads via the graph VJP


# legacy flat Runtime field -> TPConfig field
_LEGACY_TP = {
    "tp_mode": "mode",
    "sequence_parallel": "sequence_parallel",
    "cais_chunks": "chunks",
    "cais_bidirectional": "bidirectional",
    "tp_microbatches": "microbatches",
    "tp_planner": "planner",
}


def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"Runtime.{name} is deprecated; use Runtime.tp "
        f"(TPConfig.{_LEGACY_TP[name]})", DeprecationWarning, stacklevel=3)


@dataclass(frozen=True, init=False)
class Runtime:
    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # distribution: ALL tensor-parallel knobs (see TPConfig)
    tp: TPConfig = TPConfig()
    # memory
    remat: bool = True                  # activation checkpointing per period
    loss_chunk: int = 512               # CE computed in seq chunks (big vocabs)
    # decode KV-cache placement: "context" shards the cache sequence dim over
    # the TP axis (context parallelism); "batch_only" replicates it there
    cache_layout: str = "context"
    # optimizer distribution
    zero_sharding: bool = True          # shard optimizer state over DP axes

    def __init__(self, compute_dtype: str = "bfloat16",
                 param_dtype: str = "float32",
                 tp: Optional[TPConfig] = None,
                 remat: bool = True, loss_chunk: int = 512,
                 cache_layout: str = "context", zero_sharding: bool = True,
                 **legacy):
        bad = sorted(set(legacy) - set(_LEGACY_TP))
        if bad:
            raise TypeError(
                f"Runtime() got unexpected keyword argument {bad[0]!r}")
        for name in legacy:
            _warn_legacy(name)
        tp = tp if tp is not None else TPConfig()
        if legacy:
            tp = dataclasses.replace(
                tp, **{_LEGACY_TP[k]: v for k, v in legacy.items()})
        object.__setattr__(self, "compute_dtype", compute_dtype)
        object.__setattr__(self, "param_dtype", param_dtype)
        object.__setattr__(self, "tp", tp)
        object.__setattr__(self, "remat", remat)
        object.__setattr__(self, "loss_chunk", loss_chunk)
        object.__setattr__(self, "cache_layout", cache_layout)
        object.__setattr__(self, "zero_sharding", zero_sharding)

    @property
    def dtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    # ----- deprecation shims: old flat names read through Runtime.tp -----
    @property
    def tp_mode(self) -> str:
        _warn_legacy("tp_mode")
        return self.tp.mode

    @property
    def sequence_parallel(self) -> bool:
        _warn_legacy("sequence_parallel")
        return self.tp.sequence_parallel

    @property
    def cais_chunks(self) -> Optional[int]:
        _warn_legacy("cais_chunks")
        return self.tp.chunks

    @property
    def cais_bidirectional(self) -> bool:
        _warn_legacy("cais_bidirectional")
        return self.tp.bidirectional

    @property
    def tp_microbatches(self) -> Union[int, str]:
        _warn_legacy("tp_microbatches")
        return self.tp.microbatches

    @property
    def tp_planner(self) -> str:
        _warn_legacy("tp_planner")
        return self.tp.planner


SMOKE = Runtime(compute_dtype="float32", remat=False, loss_chunk=64)
