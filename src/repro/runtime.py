"""Runtime (non-architecture) knobs: dtypes, parallelism mode, remat, CAIS.

Separated from ArchConfig so the same architecture can be lowered with
different distribution/precision strategies (baseline vs CAIS vs hillclimbed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass(frozen=True)
class Runtime:
    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # distribution
    tp_mode: str = "auto"               # any repro.core.backends name
    sequence_parallel: bool = True      # SP-TP layout (paper's primary)
    # ring chunks (merge-table analogue); None = the cais backend plans the
    # chunking per collective from payload bytes via coordination.plan()
    cais_chunks: Optional[int] = None
    cais_bidirectional: bool = True     # asymmetric/bidirectional overlap
    # period-graph batch split: the explicit model path splits each
    # layer_pattern period into this many independent microbatch chains
    # inside ONE graph/shard_map so pass 3 can cross-pair their collectives
    # (overlap_asym). int, or "auto" (coordination.plan_microbatches); 1 =
    # unsplit (bit-identical to the pre-split path). "auto" never splits
    # MoE periods — their aux loss is a per-batch statistic that splitting
    # changes, so that trade-off needs an explicit integer opt-in
    tp_microbatches: Union[int, str] = 1
    # pass-3 schedule planner for the period-graph optimizer: "greedy"
    # (deterministic nearest-independent-first pairing + α-β heuristics,
    # the default) or "perfsim" (repro.plan: simulated-makespan argmin over
    # pairings/chunks/microbatch splits, memoized under reports/plans/)
    tp_planner: str = "greedy"
    # memory
    remat: bool = True                  # activation checkpointing per period
    loss_chunk: int = 512               # CE computed in seq chunks (big vocabs)
    # decode KV-cache placement: "context" shards the cache sequence dim over
    # the TP axis (context parallelism); "batch_only" replicates it there
    cache_layout: str = "context"
    # optimizer distribution
    zero_sharding: bool = True          # shard optimizer state over DP axes

    @property
    def dtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]


SMOKE = Runtime(compute_dtype="float32", remat=False, loss_chunk=64)
