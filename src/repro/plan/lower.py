"""Lowering bridge: a :class:`repro.core.dataflow.Graph` → a
:class:`repro.core.perfsim.Sim` task DAG.

This is what puts the perfsim cost model *in the optimization loop* (the
"compute-aware" half of the paper's title): any post-pass-2 graph — sublayer,
whole block, multi-block period, microbatch-split period — lowers to COMP /
WF / WB tasks whose durations come from GEMM FLOP counts and the Fig.-10
per-direction byte accounting (:func:`repro.core.perfsim.dir_bytes`), so the
search in :mod:`repro.plan.search` can score candidate schedules by simulated
makespan instead of a greedy topological heuristic.

Shape propagation tracks GLOBAL logical shapes per value (the perfsim ``m``
convention: a collective's payload is the full gathered activation's bytes);
GEMM FLOPs are global too and divided by the TP degree at task-emission time,
exactly like :func:`repro.core.perfsim.schedule_phases`. Local math the cost
model cannot see inside (``custom`` / ``route`` / ``unroute``) lowers to a
zero-duration COMP task — it keeps the dependency structure and costs nothing,
which is conservative for *ranking* schedules because it is identical across
candidates. Per-node FLOP hints (``comp_hints``) override that default.

The chunk-granularity lowering mirrors ``schedule_phases``' CAIS branch: wire
chains free-run with cross-phase continuity, ``serial_frac`` of each chunk's
compute trails its arriving data, and ``overlap_asym`` interleaves its RS and
AG sides chunk-by-chunk on the shared WF/WB resources — which is precisely
why an up-dominated RS paired with a down-dominated AG beats two serial
collectives, and what the search exploits when it picks pairings.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import dataflow as df
from repro.core import perfsim as ps
from repro.core.perfsim import COMP, WB, WF, Fabric, Policy, Sim

# Backend name → perfsim schedule policy. "cais" is the paper's chunked
# bidirectional schedule; "barrier" is the monolithic NVLS-style phase
# structure; anything unknown falls back to barrier (the conservative model).
_POLICIES = {
    "cais": ps.BASELINES["CAIS"],
    "barrier": ps.BASELINES["SP-NVLS"],
}


def policy_for_backend(backend: str, num_chunks: Optional[int] = None
                       ) -> Policy:
    """The perfsim :class:`Policy` modelling a collective backend, with an
    optional per-collective chunk override."""
    import dataclasses

    p = _POLICIES.get(backend, _POLICIES["barrier"])
    if num_chunks:
        p = dataclasses.replace(p, chunks=int(num_chunks))
    return p


def fabric_from_hw(hw, n: int, mxu_eff: float = 0.55,
                   n_outer: int = 1) -> Fabric:
    """A perfsim fabric from a :class:`repro.hw.HWSpec` — the bridge the
    ``tp.sp_period`` planner path uses so the cost model and the α-β
    coordination planner read the same target-hardware numbers.
    ``n_outer > 1`` builds a two-tier fabric for a hierarchical 2D-TP mesh:
    the inter-node tier reads the spec's DCN α-β terms, so the planner can
    price (and chunk) each tier separately (docs/topology.md)."""
    f = Fabric(n=n, bw=hw.ici_bw, alpha=hw.hop_latency,
               peak=hw.peak_flops, mxu_eff=mxu_eff)
    if n_outer > 1:
        import dataclasses
        f = dataclasses.replace(
            f, n_outer=int(n_outer),
            bw2=getattr(hw, "dcn_bw", hw.ici_bw),
            alpha2=getattr(hw, "dcn_latency", hw.hop_latency))
    return f


def synthesize_shapes(g: df.Graph, batch: int = 8, seq: int = 512,
                      model_dim: int = 1024
                      ) -> Tuple[Dict[str, tuple], Dict[str, tuple]]:
    """Default (value_shapes, weight_shapes) for a graph whose real shapes
    are unknown (``dataflow.optimize(planner="perfsim")`` called outside the
    model path): every graph input is a (batch, seq, model_dim) activation
    and every GEMM is square. Uniform sizes still rank *pairings* correctly
    on symmetric graphs — the ranking then depends only on schedule
    structure, which is what the planner decides."""
    value_shapes = {}
    weight_shapes: Dict[str, tuple] = {}
    for n in g.nodes:
        if n.op == "input":
            value_shapes[n.name] = (batch, seq, model_dim)
        for w in n.weights:
            weight_shapes.setdefault(w, (model_dim, model_dim))
    return value_shapes, weight_shapes


@dataclass
class _State:
    """Wire/compute chain continuity across phases (schedule_phases' wdep /
    gdep), plus the value → exit-task map the node walk threads through."""

    wdep: Dict[str, Optional[int]] = field(
        default_factory=lambda: {WF: None, WB: None})
    gdep: Optional[int] = None
    exits: Dict[str, Tuple[int, ...]] = field(default_factory=dict)


class Lowering:
    """One lowering of a graph onto a :class:`Sim`.

    Parameters
    ----------
    fabric / policy:
        The cost model (``fabric.n`` is the TP ring size).
    value_shapes:
        Global logical shape per graph ``input`` value.
    weight_shapes:
        Global logical shape per weight key (2-D entries are GEMM weights;
        1-D norm scales are ignored for FLOPs).
    dtype_bytes:
        Activation element size (payload bytes = prod(shape) · dtype_bytes).
    num_chunks:
        Per-collective chunk override (None → ``policy.chunks``). On a
        two-tier fabric an ``(inner, outer)`` pair sets a DIFFERENT chunk
        count per tier — the per-axis chunking the planner sweeps.
    comp_hints:
        Optional node-name → global FLOPs for fn-carrying local math.
    """

    def __init__(self, fabric: Fabric, policy: Policy,
                 value_shapes: Dict[str, tuple],
                 weight_shapes: Dict[str, tuple],
                 dtype_bytes: int = 4,
                 num_chunks=None,
                 comp_hints: Optional[Dict[str, float]] = None):
        self.f = fabric
        self.p = policy
        self.value_shapes = dict(value_shapes)
        self.weight_shapes = dict(weight_shapes)
        self.dtype_bytes = int(dtype_bytes)
        if isinstance(num_chunks, (tuple, list)):
            self.chunks = int(num_chunks[0] or policy.chunks)
            self.chunks_outer = int(num_chunks[-1] or policy.chunks)
        else:
            self.chunks = int(num_chunks or policy.chunks)
            self.chunks_outer = self.chunks
        self.comp_hints = dict(comp_hints or {})

    # -- shape/cost helpers -------------------------------------------------

    def _bytes(self, shape: tuple) -> float:
        return float(math.prod(shape)) * self.dtype_bytes

    def _gemm_flops(self, in_shape: tuple, wkeys: Sequence[str]) -> float:
        """Σ 2·(tokens)·din·dout over the GEMM (2-D) weights of a fused op."""
        tokens = math.prod(in_shape[:-1])
        total = 0.0
        for k in wkeys:
            w = self.weight_shapes.get(k)
            if w is not None and len(w) == 2:
                total += 2.0 * tokens * w[0] * w[1]
        return total

    def _gemm_outs(self, in_shape: tuple, wkeys: Sequence[str]) -> list:
        return [in_shape[:-1] + (w[1],)
                for k in wkeys
                if (w := self.weight_shapes.get(k)) is not None
                and len(w) == 2]

    # -- task emission ------------------------------------------------------

    def _comp(self, sim: Sim, st: _State, flops: float, deps) -> List[int]:
        dur = flops / self.f.n / (self.f.peak * self.f.mxu_eff) \
            * self.p.compute_mult
        return [sim.add(COMP, dur, tuple(deps))]

    def _legs(self, coll: str, m: float) -> List[tuple]:
        """The per-tier wire legs of one collective:
        ``(coll, payload, ring, bw, alpha, chunks, carries_compute)``.
        Single-tier fabrics emit one leg. Two-tier fabrics decompose the
        way the hierarchical backends execute (docs/topology.md): AG =
        inter-node exchange then intra-node gather; RS = intra-node scatter
        then inter-node exchange; AR = intra-RS → inter-AR → intra-AG. The
        inter-node leg moves 1/n_inner of the gathered payload on the
        (bw2, alpha2) tier with its own chunk count. The fused GEMM always
        rides the compute-adjacent INNER leg."""
        f = self.f
        if not f.two_tier:
            return [(coll, m, f.n, f.bw, f.alpha, self.chunks, True)]
        n_in = f.n_inner
        a2 = f.alpha2 if f.alpha2 is not None else f.alpha

        def inner(cl, comp):
            return (cl, m, n_in, f.bw, f.alpha, self.chunks, comp)

        def outer(cl):
            return (cl, m / n_in, f.n_outer, f.bw2, a2,
                    self.chunks_outer, False)

        if coll == "ag":
            return [outer("ag"), inner("ag", True)]
        if coll == "rs":
            return [inner("rs", True), outer("rs")]
        return [inner("rs", True), outer("ar"), inner("ag", False)]

    def _leg_phase(self, sim: Sim, st: _State, flops: float, m: float,
                   coll: str, n: int, bw: float, alpha: float, chunks: int,
                   deps: Sequence[int]) -> List[int]:
        """One wire leg (+ its riding GEMM compute, if any) under the
        policy's granularity. Returns the exit task ids."""
        f, p = self.f, self.p
        t_comp = flops / f.n / (f.peak * f.mxu_eff) * p.compute_mult
        bf, bb = ps.dir_bytes(p, coll, m, n)

        if p.granularity == "barrier":
            g = sim.add(COMP, t_comp, tuple(deps))
            fb = f if (bw == f.bw and alpha == f.alpha) else \
                ps.replace(f, bw=bw, alpha=alpha)
            ws = ps._emit_barrier_wire(sim, bf, bb, fb, p, (g,),
                                       chunks=max(1, n - 1))
            return ws or [g]

        # chunk granularity (cais): wire chains free-run with continuity
        # across phases; serial_frac of per-chunk compute trails its data
        c = chunks
        last: List[int] = []
        for _ in range(c):
            ws: List[int] = []
            for res, b in ((WF, bf), (WB, bb)):
                if b <= 0:
                    continue
                wdeps = ([st.wdep[res]] if st.wdep[res] is not None
                         else list(deps))
                w = sim.add(res, b / c / bw + alpha, wdeps)
                st.wdep[res] = w
                ws.append(w)
            gs = sim.add(COMP, p.serial_frac * t_comp / c, ws or list(deps))
            g = sim.add(COMP, (1 - p.serial_frac) * t_comp / c,
                        [gs] + ([st.gdep] if st.gdep is not None else []))
            st.gdep = g
            last = [g] + ws
        return last

    def _phase(self, sim: Sim, st: _State, flops: float, m: float,
               coll: Optional[str], deps: Sequence[int]) -> List[int]:
        """One (GEMM, adjacent collective) unit — the perfsim Phase — under
        the policy's granularity, decomposed into per-tier legs on a
        two-tier fabric. Returns the exit task ids."""
        if coll is None:
            return self._comp(sim, st, flops, deps)
        out = list(deps)
        for lcoll, lm, ln, lbw, lalpha, lc, carries in self._legs(coll, m):
            out = self._leg_phase(sim, st, flops if carries else 0.0, lm,
                                  lcoll, ln, lbw, lalpha, lc, out)
        return out

    def _overlap_phases(self, sim: Sim, st: _State,
                        sides: List[Tuple[float, float, str]],
                        deps: Sequence[int]) -> List[int]:
        """Co-scheduled phases (overlap_asym): chunk s of every side is
        emitted before chunk s+1 of any, so the sides' complementary wire
        directions interleave on the shared WF/WB resources — the Fig. 9e
        asymmetric overlap. Under barrier granularity the sides just
        serialize (a barrier backend cannot overlap them)."""
        f, p = self.f, self.p
        if p.granularity == "barrier":
            out: List[int] = []
            for flops, m, coll in sides:
                out += self._phase(sim, st, flops, m, coll, deps)
            return out
        # Two-tier fabric: only the compute-adjacent INNER legs interleave
        # (ring n_inner); an AG side's inter-node exchange precedes its
        # chunks, an RS/AR side's trails them — the outer tier cannot be
        # chunk-interleaved by an intra-node merge table.
        two = f.two_tier
        n_ring = f.n_inner if two else f.n
        side_deps: List[List[int]] = [list(deps) for _ in sides]
        inner_colls: List[str] = []
        for i, (flops, m, coll) in enumerate(sides):
            inner_colls.append("rs" if (two and coll == "ar") else coll)
            if two and coll == "ag":
                side_deps[i] = list(self._leg_phase(
                    sim, st, 0.0, m / n_ring, "ag", f.n_outer, f.bw2,
                    f.alpha2 if f.alpha2 is not None else f.alpha,
                    self.chunks_outer, side_deps[i]))
        c = self.chunks
        gdeps: List[Optional[int]] = [st.gdep] * len(sides)
        last: List[int] = []
        for _ in range(c):
            step: List[int] = []
            for i, (flops, m, coll) in enumerate(sides):
                t_comp = flops / f.n / (f.peak * f.mxu_eff) * p.compute_mult
                bf, bb = ps.dir_bytes(p, inner_colls[i], m, n_ring)
                ws: List[int] = []
                for res, b in ((WF, bf), (WB, bb)):
                    if b <= 0:
                        continue
                    wdeps = ([st.wdep[res]] if st.wdep[res] is not None
                             else side_deps[i])
                    w = sim.add(res, b / c / f.bw + f.alpha, wdeps)
                    st.wdep[res] = w
                    ws.append(w)
                gs = sim.add(COMP, p.serial_frac * t_comp / c,
                             ws or side_deps[i])
                g = sim.add(COMP, (1 - p.serial_frac) * t_comp / c,
                            [gs] + ([gdeps[i]] if gdeps[i] is not None
                                    else []))
                gdeps[i] = g
                step += [g] + ws
            last = step
        st.gdep = max(g for g in gdeps if g is not None) \
            if any(g is not None for g in gdeps) else st.gdep
        if two:
            a2 = f.alpha2 if f.alpha2 is not None else f.alpha
            for i, (flops, m, coll) in enumerate(sides):
                if coll not in ("rs", "ar"):
                    continue
                dep = [gdeps[i]] if gdeps[i] is not None else list(deps)
                t = self._leg_phase(sim, st, 0.0, m / n_ring,
                                    "rs" if coll == "rs" else "ar",
                                    f.n_outer, f.bw2, a2,
                                    self.chunks_outer, dep)
                if coll == "ar":
                    t = self._leg_phase(sim, st, 0.0, m, "ag", n_ring,
                                        f.bw, f.alpha, self.chunks, t)
                last = last + list(t)
        return last

    # -- the node walk ------------------------------------------------------

    def lower(self, g: df.Graph) -> Sim:
        """Emit the whole graph (nodes in topo order) onto a fresh Sim."""
        sim = Sim()
        st = _State()
        shapes = dict(self.value_shapes)
        nodes = df._topo(list(g.nodes), g.outputs)

        def deps_of(n: df.Node) -> List[int]:
            out: List[int] = []
            for v in n.inputs:
                out += st.exits.get(v, ())
            return out

        def set_exits(n: df.Node, tids: Sequence[int],
                      out_shapes: Sequence[tuple]):
            for v, s in zip(n.outputs, out_shapes):
                shapes[v] = s
                st.exits[v] = tuple(tids)

        for n in nodes:
            if n.op == "input":
                if n.name not in shapes:
                    raise KeyError(
                        f"lowering needs a value shape for graph input "
                        f"{n.name!r}")
                st.exits[n.name] = ()
                continue
            deps = deps_of(n)
            ins = [shapes[v] for v in n.inputs]
            x = ins[0]

            if n.op in ("gemm_col", "gemm_row"):
                outs = self._gemm_outs(x, n.weights) or [x]
                t = self._comp(sim, st, self._gemm_flops(x, n.weights), deps)
                set_exits(n, t, outs)
            elif n.op in ("allgather", "reduce_scatter", "allreduce"):
                coll = {"allgather": "ag", "reduce_scatter": "rs",
                        "allreduce": "ar"}[n.op]
                t = self._phase(sim, st, 0.0, self._bytes(x), coll, deps)
                set_exits(n, t, [x])
            elif n.op in ("layernorm", "add", "residual", "custom",
                          "route", "unroute"):
                t = self._comp(sim, st, self.comp_hints.get(n.name, 0.0),
                               deps)
                set_exits(n, t, [x] * len(n.outputs))
            elif n.op == "a2a_ffn":
                # expert all-to-all: dispatch + combine each move the send
                # buffer once per direction (ar-like both-direction traffic)
                t = self._phase(sim, st,
                                self.comp_hints.get(n.name, 0.0),
                                self._bytes(x), "ar", deps)
                set_exits(n, t, [x])
            elif n.op == "bwd_a2a_ffn":
                # adjoint expert all-to-all: the grad dispatch carries the
                # send buffer AND the output cotangent (2× the forward
                # payload per direction), the combine returns the chunk
                # cotangents; the expert-VJP FLOPs come from comp_hints
                # (tp._bwd_planner doubles the forward hint for adj. nodes)
                m = self._bytes(x) + self._bytes(ins[1])
                t = self._phase(sim, st,
                                self.comp_hints.get(n.name, 0.0), m,
                                "ar", deps)
                set_exits(n, t, [x] + [self.weight_shapes.get(k, x)
                                       for k in n.weights])
            elif n.op in ("ag_gemm", "ag_gemm_multi"):
                outs = self._gemm_outs(x, n.weights) or [x]
                t = self._phase(sim, st, self._gemm_flops(x, n.weights),
                                self._bytes(x), "ag", deps)
                set_exits(n, t, outs)
            elif n.op in ("gemm_rs", "gemm_ar"):
                outs = self._gemm_outs(x, n.weights) or [x]
                coll = "rs" if n.op == "gemm_rs" else "ar"
                t = self._phase(sim, st, self._gemm_flops(x, n.weights),
                                self._bytes(outs[0]), coll, deps)
                set_exits(n, t, outs)
            elif n.op == "bwd_ag_gemm":
                # adjoint of gemm_rs (docs/training.md): AG the seq-sharded
                # cotangent (payload = the full gathered cotangent, same
                # convention as ag_gemm), GEMM against the transposed
                # weight; the gathered cotangent re-exposes for dw consumers
                outs = self._gemm_outs(x, n.weights) or [x]
                t = self._phase(sim, st, self._gemm_flops(x, n.weights),
                                self._bytes(x), "ag", deps)
                set_exits(n, t, outs + [x])
            elif n.op in ("fused_rs_ln_ag", "fused_rs_ln_ag_multi",
                          "fused_rs_ln"):
                # weights = (w1, scale, *w2s): the RS-side GEMM, the norm
                # scale, then the AG-side GEMM weights (absent in
                # fused_rs_ln). Phase 1: gemm→RS of z; phase 2: AG→gemms.
                w1 = n.weights[0]
                z = self._gemm_outs(x, (w1,))
                z_shape = z[0] if z else x
                t1 = self._phase(sim, st, self._gemm_flops(x, (w1,)),
                                 self._bytes(z_shape), "rs", deps)
                if n.op == "fused_rs_ln":
                    set_exits(n, t1, [z_shape, z_shape])
                else:
                    w2s = n.weights[2:]
                    outs = self._gemm_outs(z_shape, w2s) or [z_shape]
                    t2 = self._phase(sim, st,
                                     self._gemm_flops(z_shape, w2s),
                                     self._bytes(z_shape), "ag", t1)
                    set_exits(n, t2, outs + [z_shape])
            elif n.op == "overlap_asym":
                # inputs = (x_rs, x_ag); weights = (w_rs, *w_ags)
                x_rs, x_ag = ins[0], ins[1]
                w_rs, w_ags = n.weights[0], n.weights[1:]
                rs_out = self._gemm_outs(x_rs, (w_rs,))
                rs_shape = rs_out[0] if rs_out else x_rs
                ag_outs = self._gemm_outs(x_ag, w_ags) or [x_ag]
                t = self._overlap_phases(
                    sim, st,
                    [(self._gemm_flops(x_rs, (w_rs,)),
                      self._bytes(rs_shape), "rs"),
                     (self._gemm_flops(x_ag, w_ags),
                      self._bytes(x_ag), "ag")],
                    deps)
                set_exits(n, t, [rs_shape] + ag_outs)
            else:
                raise ValueError(f"lowering does not know op {n.op!r}")
        return sim


def lower_graph(g: df.Graph, fabric: Fabric, policy: Policy,
                value_shapes: Optional[Dict[str, tuple]] = None,
                weight_shapes: Optional[Dict[str, tuple]] = None,
                dtype_bytes: int = 4,
                num_chunks=None,
                comp_hints: Optional[Dict[str, float]] = None) -> Sim:
    """Convenience wrapper: lower ``g`` with (possibly synthesized) shapes."""
    if value_shapes is None or weight_shapes is None:
        vs, ws = synthesize_shapes(g)
        value_shapes = {**vs, **(value_shapes or {})}
        weight_shapes = {**ws, **(weight_shapes or {})}
    return Lowering(fabric, policy, value_shapes, weight_shapes,
                    dtype_bytes, num_chunks, comp_hints).lower(g)


def simulate(g: df.Graph, fabric: Fabric, policy: Policy,
             **kw) -> float:
    """Simulated makespan (seconds) of graph ``g`` under the cost model."""
    makespan, _ = lower_graph(g, fabric, policy, **kw).run()
    return makespan
