"""Calibration: fit the planner's fabric parameters from measured bench rows.

The UMD multi-node-inference study (PAPERS.md) makes the case that analytic
cost models are only trustworthy for schedule tuning once their parameters
are fitted to measurements of the actual platform. Here the measurements are
the ``benchmarks/sublayer.py`` wall-clock cells committed as
``$REPRO_BENCH_JSON`` (``BENCH_pr10.json``): each *barrier* cell is rebuilt as
the very dataflow graph the bench timed (1-block, 2-block period, and the
microbatch-split period at the ``REPRO_BENCH_TINY`` shapes), lowered through
:mod:`repro.plan.lower`, and the fabric's effective (``mxu_eff``, ``bw``,
``alpha``) are fitted by log-space coordinate descent so simulated and
measured times agree.

A second pass fits the inter-node tier (docs/topology.md): when the bench
artifact carries the 2D-mesh barrier cell (``topo.flat_vs_2d.barrier``,
measured on a ``tp_in × tp_out`` hierarchical mesh), the intra-node fit is
frozen and (``bw2``, ``alpha2``) of the two-tier fabric are fitted against
it by the same descent, so the perfsim planner can price the two tiers
differently (``CalibrationResult.fabric2``).

Only the ``barrier`` cells feed the fit: the measured cells run on
CPU-emulated virtual devices where ``collective_permute`` chains serialize,
so the ``cais`` wall-clocks are explicitly informational (the bench says so
in its provenance row) and would poison the fit. The residual after fitting
is pinned by ``tests/test_planner.py``: every cell's simulated/measured
ratio must stay within ``exp(±RATIO_TOLERANCE)`` — the documented agreement
band (see ``docs/planner.md``). The tolerance is loose because a 3-resource
list-schedule over an emulated CPU platform is a trend model, not a cycle
model; what the pin buys is that the calibration *plumbing* (graph rebuild →
lowering → fit) cannot silently rot.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import dataflow as df
from repro.core.perfsim import Fabric
from repro.plan import lower as lower_mod

# max |ln(simulated / measured)| per fitted cell — the documented band
# (BENCH_pr10.json fits at ≈0.23; the slack absorbs runner timing noise when
# the baseline is regenerated, without letting the fit silently diverge).
RATIO_TOLERANCE = 0.6

# REPRO_BENCH_TINY shapes of benchmarks/sublayer.py's measured cells
_TINY = dict(B=2, S=256, d=128, d_ff=256, n=8, dtype_bytes=4)

# bench row name → (number of blocks, microbatch split)
BARRIER_CELLS: Dict[str, Tuple[int, int]] = {
    "block.fused_vs_split.barrier": (1, 1),
    "period.graph_vs_perblock.barrier": (2, 1),
    "period.split_vs_unsplit.barrier": (2, 2),
}

# 2D-mesh bench row → (blocks, microbatch split, n_outer). Measured on the
# hierarchical tp_in × tp_out mesh; feeds the (bw2, alpha2) inter-tier fit.
TOPO_CELLS: Dict[str, Tuple[int, int, int]] = {
    "topo.flat_vs_2d.barrier": (1, 1, 4),
}


@dataclass(frozen=True)
class CalibrationResult:
    fabric: Fabric                      # the fitted cost-model fabric
    ratios: Dict[str, float]            # cell → simulated / measured
    max_abs_log_ratio: float            # worst-cell |ln ratio| after the fit
    fabric2: Optional[Fabric] = None    # two-tier fabric (bw2/alpha2 fitted)

    @property
    def within_tolerance(self) -> bool:
        return self.max_abs_log_ratio <= RATIO_TOLERANCE


def _tiny_weight_shapes(blocks: int) -> Dict[str, tuple]:
    d, d_ff = _TINY["d"], _TINY["d_ff"]
    out: Dict[str, tuple] = {}
    for i in range(blocks):
        p = f"b{i}."
        out.update({p + "scale1": (d,), p + "scale2": (d,),
                    p + "wq": (d, d), p + "wk": (d, d), p + "wv": (d, d),
                    p + "wo": (d, d), p + "w_up": (d, d_ff),
                    p + "w_gate": (d, d_ff), p + "w_down": (d_ff, d)})
    return out


def _cell_graph(blocks: int, mb: int) -> df.Graph:
    """The optimized graph the bench cell executed (dummy attention core —
    the lowering never looks inside local math)."""
    from repro.core import tp as tp_mod

    core = lambda q, k, v: q                               # noqa: E731
    base = tp_mod.dense_period_graph([core] * blocks, has_gate=True,
                                     act="silu")
    merged = base if mb <= 1 else df.merge_graphs([base] * mb,
                                                  share_weights=True)
    return df.optimize(merged)


def _cell_shapes(blocks: int, mb: int):
    B, S, d = _TINY["B"], _TINY["S"], _TINY["d"]
    if mb <= 1:
        values = {"x": (B, S, d)}
    else:
        values = {f"mb{i}.x": (max(B // mb, 1), S, d) for i in range(mb)}
    return values, _tiny_weight_shapes(blocks)


def load_rows(path: str) -> Dict[str, float]:
    """``{row name: us_per_call}`` from a bench JSON artifact."""
    with open(path) as fh:
        rows = json.load(fh)
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def _predictor(cells: Dict[str, Tuple[int, int]]):
    """Precompile the per-cell (graph, shapes) so the fit loop only re-lowers
    with new fabric parameters."""
    compiled = []
    policy = lower_mod.policy_for_backend("barrier")
    for name, (blocks, mb) in cells.items():
        g = _cell_graph(blocks, mb)
        values, weights = _cell_shapes(blocks, mb)
        compiled.append((name, g, values, weights))

    def predict(fabric: Fabric) -> Dict[str, float]:
        return {name: lower_mod.simulate(
            g, fabric, policy, value_shapes=values, weight_shapes=weights,
            dtype_bytes=_TINY["dtype_bytes"])
            for name, g, values, weights in compiled}

    return predict


def calibrate(rows, cells: Optional[Dict[str, Tuple[int, int]]] = None,
              base: Optional[Fabric] = None) -> CalibrationResult:
    """Fit (``mxu_eff``, ``bw``, ``alpha``) so the lowered barrier cells'
    simulated makespans match the measured wall-clocks in ``rows`` (a path
    to a bench JSON, or a ``{name: us_per_call}`` dict). Log-space
    coordinate descent — each parameter scales its term monotonically, so a
    shrinking multiplicative grid converges; deterministic by construction.
    """
    if isinstance(rows, str):
        rows = load_rows(rows)
    cells = dict(cells or BARRIER_CELLS)
    missing = [c for c in cells if c not in rows]
    if missing:
        raise KeyError(f"bench rows missing calibration cells: {missing}")
    measured = {c: rows[c] * 1e-6 for c in cells}          # us → s
    predict = _predictor(cells)

    f = base or Fabric(n=_TINY["n"])

    def loss(fab: Fabric) -> float:
        pred = predict(fab)
        return sum((math.log(max(pred[c], 1e-12)) -
                    math.log(max(measured[c], 1e-12))) ** 2 for c in cells)

    f = _descent(f, ("mxu_eff", "bw", "alpha"), loss)

    pred = predict(f)
    ratios = {c: pred[c] / measured[c] for c in cells}

    # second pass: inter-node tier. Freeze the intra-node fit, seed the
    # outer tier from it, and fit (bw2, alpha2) against the 2D-mesh cells.
    fabric2 = None
    topo = {c: v for c, v in TOPO_CELLS.items() if c in rows}
    if topo:
        measured2 = {c: rows[c] * 1e-6 for c in topo}
        policy2 = lower_mod.policy_for_backend("barrier")
        compiled2 = []
        for name, (blocks, mb, n_outer) in topo.items():
            values, weights = _cell_shapes(blocks, mb)
            compiled2.append((name, _cell_graph(blocks, mb), values, weights,
                              n_outer))

        def predict2(fab: Fabric) -> Dict[str, float]:
            return {name: lower_mod.simulate(
                g, dataclasses.replace(fab, n_outer=n_o), policy2,
                value_shapes=values, weight_shapes=weights,
                dtype_bytes=_TINY["dtype_bytes"])
                for name, g, values, weights, n_o in compiled2}

        def loss2(fab: Fabric) -> float:
            pred2 = predict2(fab)
            return sum((math.log(max(pred2[c], 1e-12)) -
                        math.log(max(measured2[c], 1e-12))) ** 2
                       for c in topo)

        fabric2 = dataclasses.replace(f, bw2=f.bw, alpha2=f.alpha)
        fabric2 = _descent(fabric2, ("bw2", "alpha2"), loss2)
        pred2 = predict2(fabric2)
        ratios.update({c: pred2[c] / measured2[c] for c in topo})

    max_err = max(abs(math.log(r)) for r in ratios.values())
    return CalibrationResult(fabric=f, ratios=ratios,
                             max_abs_log_ratio=max_err, fabric2=fabric2)


def _descent(f: Fabric, params: Tuple[str, ...], loss) -> Fabric:
    """Log-space coordinate descent: each parameter scales its cost term
    monotonically, so a shrinking multiplicative grid converges;
    deterministic by construction."""
    for span in (256.0, 16.0, 4.0, 2.0, 1.25, 1.06):
        for p in params:
            cur = getattr(f, p)
            best_v, best_l = cur, loss(f)
            for k in range(-4, 5):
                v = cur * span ** (k / 4.0)
                if p == "mxu_eff":
                    v = min(v, 1.0)
                cand = dataclasses.replace(f, **{p: v})
                l = loss(cand)
                if l < best_l - 1e-15:
                    best_v, best_l = v, l
            f = dataclasses.replace(f, **{p: best_v})
    return f
