"""Per-(graph signature, shapes, dtype, topology, backend) plan cache.

The search in :mod:`repro.plan.search` costs many simulated lowerings per
graph; production ``tp.sp_period`` calls re-trace the SAME (shape, topology)
cell over and over, so plans persist as JSON under ``reports/plans/`` (one
file per key) and repeated calls hit the precomputed plan. Keys are sha-256
over a canonical serialization — node structure (names/ops/edges/weights),
value/weight shapes, dtype bytes, fabric parameters, backend, and the
candidate space — so any input that could change the argmin changes the key.
Hit/miss counts are exposed via :attr:`PlanCache.stats` (observable, and
pinned deterministic by ``tests/test_planner.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Optional

from repro.core import dataflow as df

DEFAULT_ROOT = os.environ.get("REPRO_PLAN_CACHE", "reports/plans")


def graph_signature(g: df.Graph) -> str:
    """Canonical structural serialization of a graph (topo order; ``fn``
    closures excluded — the cost model never looks inside local math)."""
    nodes = df._topo(list(g.nodes), g.outputs)
    return json.dumps(
        [[n.name, n.op, list(n.inputs), list(n.weights), list(n.outputs)]
         for n in nodes] + [list(g.outputs)],
        separators=(",", ":"))


def plan_key(g: df.Graph, value_shapes: Dict[str, tuple],
             weight_shapes: Dict[str, tuple], dtype_bytes: int,
             fabric, backend: str, extra: Optional[dict] = None) -> str:
    """The cache key: sha-256 hex digest over everything the argmin depends
    on. ``extra`` carries search-space knobs (microbatch/chunk candidates)."""
    payload = {
        "graph": graph_signature(g),
        "values": sorted((k, list(v)) for k, v in value_shapes.items()),
        "weights": sorted((k, list(v)) for k, v in weight_shapes.items()),
        "dtype_bytes": int(dtype_bytes),
        "fabric": dataclasses.asdict(fabric),
        "backend": str(backend),
        "extra": extra or {},
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class PlanCache:
    """JSON-persisted plan store with observable hit/miss counters.

    ``get`` returns the stored plan dict (or None); ``put`` persists one.
    The in-memory layer makes repeated hits within a process cheap; the disk
    layer makes them survive across processes (CI uploads the directory as
    an artifact)."""

    def __init__(self, root: str = DEFAULT_ROOT):
        self.root = root
        self.hits = 0
        self.misses = 0
        self._mem: Dict[str, dict] = {}

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def get(self, key: str) -> Optional[dict]:
        if key in self._mem:
            self.hits += 1
            return self._mem[key]
        path = self._path(key)
        if os.path.exists(path):
            try:
                with open(path) as fh:
                    plan = json.load(fh)
            except (OSError, json.JSONDecodeError):
                self.misses += 1
                return None
            self._mem[key] = plan
            self.hits += 1
            return plan
        self.misses += 1
        return None

    def put(self, key: str, plan: dict) -> None:
        self._mem[key] = plan
        os.makedirs(self.root, exist_ok=True)
        tmp = self._path(key) + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(plan, fh, indent=1, sort_keys=True)
        os.replace(tmp, self._path(key))

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}


_DEFAULT: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """The process-wide cache the ``tp.sp_period`` planner path uses."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = PlanCache()
    return _DEFAULT
