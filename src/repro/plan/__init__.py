"""``repro.plan`` — the perfsim-in-the-loop schedule planner (paper §III-C's
"compute-aware" leg): lowering bridge (:mod:`repro.plan.lower`), schedule
search (:mod:`repro.plan.search`), measurement calibration
(:mod:`repro.plan.calibrate`) and the per-(shape, topology) plan cache
(:mod:`repro.plan.cache`). ``python -m repro.plan --selfcheck`` round-trips
lower → search → cache on the canonical sublayer graphs with no devices.
See ``docs/planner.md``.
"""
from repro.plan.cache import (PlanCache, default_cache, graph_signature,
                              plan_key)
from repro.plan.calibrate import (RATIO_TOLERANCE, CalibrationResult,
                                  calibrate)
from repro.plan.lower import (Lowering, fabric_from_hw, lower_graph,
                              policy_for_backend, simulate,
                              synthesize_shapes)
from repro.plan.search import (CHUNK_CANDIDATES, FixedPairing,
                               PerfsimPlanner, Plan, enumerate_pairings,
                               microbatch_comp_hints,
                               microbatch_value_shapes, period_planner,
                               search_pairing, search_period)

__all__ = [
    "CHUNK_CANDIDATES", "CalibrationResult", "FixedPairing", "Lowering",
    "PerfsimPlanner", "Plan", "PlanCache", "RATIO_TOLERANCE", "calibrate",
    "default_cache", "enumerate_pairings", "fabric_from_hw",
    "graph_signature", "lower_graph", "microbatch_comp_hints",
    "microbatch_value_shapes", "period_planner", "plan_key",
    "policy_for_backend", "search_pairing", "search_period", "simulate",
    "synthesize_shapes",
]
