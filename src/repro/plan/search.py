"""Schedule search: enumerate candidate decisions, score each by simulated
makespan over the lowering bridge, return the argmin as a :class:`Plan`.

Three decision axes (the knobs the greedy pipeline fixes by heuristic):

* **pass-3 pairings** — not just nearest-independent-first: a bounded DFS
  over the pairing state space (each fusion changes which pairs remain
  legal, so this is a real search tree, branch-bounded and deduped on the
  final pair *set*, which determines the final graph);
* **num_chunks** per collective (the merge-table granularity);
* **num_microbatches** — how many independent chains a period graph splits
  into (:func:`search_period`), trading pass-3 pairing opportunities against
  per-chain payloads near the hop-latency floor.

The greedy choice is always in the candidate set (the DFS's first branch at
every level IS the greedy pick), so the argmin's simulated makespan is ≤ the
greedy schedule's by construction — the acceptance bar the planner tests pin.

All of this is generic over merged forward+backward training graphs: the
backward vocabulary (``bwd_ag_gemm``, ``bwd_a2a_ffn``, backward ``gemm_ar``
/ ``gemm_rs``) lowers through the same bridge, and
:func:`repro.core.dataflow.asymmetric_candidates` ranks cross-direction
pairs first (one op downstream of a ``d.*`` cotangent seed, one not), so the
search naturally overlaps e.g. microbatch-1's backward grad-a2a/RS against
microbatch-0's forward gathers in an MoE training period.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import dataflow as df
from repro.core.perfsim import Fabric
from repro.plan import cache as cache_mod
from repro.plan import lower as lower_mod

# chunk candidates the search sweeps for chunk-granularity backends
# (None = the policy's own default)
CHUNK_CANDIDATES: Tuple[Optional[int], ...] = (None, 2, 4, 16)

# extra (inner, outer) chunk pairs swept on two-tier fabrics — the per-axis
# chunking a hierarchical 2D mesh makes available (the slow inter-node tier
# usually wants fewer, larger chunks than the intra-node ring)
TIER_CHUNK_CANDIDATES: Tuple[Tuple[int, int], ...] = \
    ((2, 1), (4, 1), (4, 2), (16, 2), (16, 4), (2, 4))


@dataclass(frozen=True)
class Plan:
    """One schedule decision: the ordered pass-3 pairing, the collective
    chunking, the period split — plus the simulated evidence for it."""

    pairing: Tuple[Tuple[str, str], ...]
    num_chunks: object      # None | int | (inner, outer) on two-tier fabrics
    num_microbatches: int
    makespan: float
    greedy_makespan: float
    backend: str

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["pairing"] = [list(p) for p in self.pairing]
        return d

    @staticmethod
    def from_dict(d: dict) -> "Plan":
        nc = d["num_chunks"]
        return Plan(pairing=tuple((p[0], p[1]) for p in d["pairing"]),
                    num_chunks=tuple(nc) if isinstance(nc, list) else nc,
                    num_microbatches=d["num_microbatches"],
                    makespan=d["makespan"],
                    greedy_makespan=d["greedy_makespan"],
                    backend=d["backend"])


def enumerate_pairings(g: df.Graph, branch: int = 3, max_states: int = 64
                       ) -> List[Tuple[Tuple[Tuple[str, str], ...],
                                       df.Graph]]:
    """Bounded DFS over pass-3 pairing sequences of a post-pass-2 graph.

    At each state the top-``branch`` candidates (nearest-first ranking) are
    explored; terminal states (no legal pair left) are collected, deduped on
    the pair *set* (same set ⇒ same final graph regardless of order). The
    unpaired graph itself is always a candidate — overlap is usually but not
    axiomatically free under the cost model. First result is always the
    greedy sequence (branch 0 at every level)."""
    results: List[Tuple[Tuple[Tuple[str, str], ...], df.Graph]] = []
    seen = set()

    def rec(cur: df.Graph, acc: List[Tuple[str, str]]):
        if len(results) >= max_states:
            return
        cands = df.asymmetric_candidates(cur)
        if not cands:
            key = frozenset(acc)
            if key not in seen:
                seen.add(key)
                results.append((tuple(acc), cur))
            return
        for a, b in cands[:branch]:
            if len(results) >= max_states:
                return
            rec(df.apply_pair(cur, a, b), acc + [(a.name, b.name)])

    rec(g, [])
    if frozenset() not in seen:
        results.append(((), g))
    return results


def search_pairing(g2: df.Graph, *,
                   fabric: Fabric,
                   backend: str = "cais",
                   value_shapes: Optional[Dict[str, tuple]] = None,
                   weight_shapes: Optional[Dict[str, tuple]] = None,
                   dtype_bytes: int = 4,
                   num_microbatches: int = 1,
                   chunk_candidates: Sequence[Optional[int]] =
                   CHUNK_CANDIDATES,
                   branch: int = 3, max_states: int = 64,
                   comp_hints: Optional[Dict[str, float]] = None) -> Plan:
    """Argmin over (pairing × num_chunks) for one post-pass-2 graph.

    Deterministic: candidate order is deterministic, and ties break toward
    the earlier candidate (strict ``<``), so the same inputs always return
    the identical Plan — the property the plan cache relies on.
    ``comp_hints`` (node name → global FLOPs for fn-carrying local math,
    e.g. attention cores) flows into every candidate's lowering so
    compute-bound pairings are weighted correctly."""
    if value_shapes is None or weight_shapes is None:
        vs, ws = lower_mod.synthesize_shapes(g2)
        value_shapes = {**vs, **(value_shapes or {})}
        weight_shapes = {**ws, **(weight_shapes or {})}

    policy = lower_mod.policy_for_backend(backend)
    if policy.granularity == "barrier":
        chunk_candidates = (None,)
    elif getattr(fabric, "two_tier", False):
        # per-axis chunking: on a two-tier fabric also sweep (inner, outer)
        # pairs so the slow tier can chunk differently from the fast one
        chunk_candidates = tuple(chunk_candidates) + tuple(
            c for c in TIER_CHUNK_CANDIDATES if c not in chunk_candidates)

    def score(graph: df.Graph, chunks) -> float:
        return lower_mod.simulate(
            graph, fabric, policy,
            value_shapes=value_shapes, weight_shapes=weight_shapes,
            dtype_bytes=dtype_bytes, num_chunks=chunks,
            comp_hints=comp_hints)

    candidates = enumerate_pairings(g2, branch=branch, max_states=max_states)
    greedy_graph = df.pair_asymmetric(g2)
    greedy_makespan = score(greedy_graph, None)

    best: Optional[Plan] = None
    for pairing, graph in candidates:
        for chunks in chunk_candidates:
            m = score(graph, chunks)
            if best is None or m < best.makespan:
                best = Plan(pairing=pairing, num_chunks=chunks,
                            num_microbatches=num_microbatches,
                            makespan=m, greedy_makespan=greedy_makespan,
                            backend=backend)
    assert best is not None
    return best


def microbatch_value_shapes(x_shape: tuple, mb: int) -> Dict[str, tuple]:
    """Input shapes of a ``merge_graphs``-split period graph: each chain's
    ``mb{i}.x`` carries 1/mb of the batch (the unsplit graph keeps ``x``)."""
    if mb <= 1:
        return {"x": tuple(x_shape)}
    per = (max(x_shape[0] // mb, 1),) + tuple(x_shape[1:])
    return {f"mb{i}.x": per for i in range(mb)}


def microbatch_comp_hints(hints: Optional[Dict[str, float]], mb: int
                          ) -> Optional[Dict[str, float]]:
    """Re-key single-chain ``comp_hints`` onto a ``merge_graphs``-split
    period graph: each chain's ``mb{i}.``-prefixed node does 1/mb of the
    base node's FLOPs (the unsplit graph keeps the base keys)."""
    if not hints:
        return None
    if mb <= 1:
        return dict(hints)
    return {f"mb{i}.{k}": v / mb
            for i in range(mb) for k, v in hints.items()}


def search_period(base: df.Graph, *,
                  fabric: Fabric,
                  backend: str = "cais",
                  x_shape: tuple,
                  weight_shapes: Dict[str, tuple],
                  dtype_bytes: int = 4,
                  mb_candidates: Sequence[int] = (1, 2, 4),
                  chunk_candidates: Sequence[Optional[int]] =
                  CHUNK_CANDIDATES,
                  branch: int = 3, max_states: int = 48,
                  comp_hints: Optional[Dict[str, float]] = None) -> Plan:
    """Joint argmin over (num_microbatches × pairing × num_chunks) for a
    single-chain period graph ``base`` (pre-optimization, input ``x`` of
    global shape ``x_shape``). Every mb candidate re-runs passes 1–2 on the
    merged graph, then the pairing search; makespans are comparable because
    every candidate schedules the same total work. ``comp_hints`` is keyed
    on BASE node names and re-prefixed per chain."""
    best: Optional[Plan] = None
    batch = int(x_shape[0])
    for mb in mb_candidates:
        if mb < 1 or (mb > 1 and (mb > batch or batch % mb)):
            continue
        merged = base if mb <= 1 else df.merge_graphs(
            [base] * mb, share_weights=True)
        g2 = df.fuse_sublayer_chain(
            df.fuse_shared_gather(df.fuse_compute_aware(merged)))
        p = search_pairing(
            g2, fabric=fabric, backend=backend,
            value_shapes=microbatch_value_shapes(x_shape, mb),
            weight_shapes=weight_shapes, dtype_bytes=dtype_bytes,
            num_microbatches=mb, chunk_candidates=chunk_candidates,
            branch=branch, max_states=max_states,
            comp_hints=microbatch_comp_hints(comp_hints, mb))
        if best is None or p.makespan < best.makespan:
            best = p
    assert best is not None
    return best


class FixedPairing:
    """A pass-3 planner that replays a decided pairing (a cache hit or a
    :func:`search_period` winner); falls back to ``base`` (a live planner)
    if the pairing no longer applies to the graph it is handed."""

    def __init__(self, plan: Plan, base: "PerfsimPlanner"):
        self.plan = plan
        self.base = base

    def pair(self, g2: df.Graph) -> df.Graph:
        try:
            return df.pair_asymmetric(g2, pairing=self.plan.pairing)
        except df.GraphError:
            out = self.base.pair(g2)
            self.plan = self.base.plan
            return out


def period_planner(base: df.Graph, *,
                   x_shape: tuple,
                   weight_shapes: Dict[str, tuple],
                   dtype_bytes: int,
                   tp: int,
                   backend: str,
                   mb_candidates: Sequence[int],
                   hw=None,
                   n_outer: int = 1,
                   cache: Optional[cache_mod.PlanCache] = None,
                   comp_hints: Optional[Dict[str, float]] = None
                   ) -> Tuple[Plan, FixedPairing]:
    """The ``tp.sp_period`` entry point: decide (num_microbatches, pairing,
    num_chunks) for one single-chain period graph, through the plan cache.

    ``x_shape`` is the per-DP-replica activation (b_loc, S, d) — the payload
    the TP collectives actually move. ``n_outer > 1`` (a hierarchical 2D
    mesh's ``tp_out`` size) builds a two-tier fabric, so the same period
    graph caches and plans DIFFERENTLY per topology — the fabric is part of
    the cache key. ``comp_hints`` (base-graph node name → FLOPs, part of
    the cache key) prices the fn-carrying local math. Returns the winning
    :class:`Plan` and a :class:`FixedPairing` to hand to
    ``dataflow.optimize(planner=...)`` for the mb-merged graph."""
    from repro.hw import V5E

    hw = hw or V5E
    fabric = lower_mod.fabric_from_hw(hw, max(tp, 2), n_outer=n_outer)
    mb_candidates = tuple(sorted(set(int(m) for m in mb_candidates))) or (1,)
    key = None
    plan: Optional[Plan] = None
    if cache is not None:
        key = cache_mod.plan_key(
            base, {"x": tuple(x_shape)}, weight_shapes, dtype_bytes, fabric,
            backend, extra={"kind": "period", "mb": list(mb_candidates),
                            "hints": sorted(
                                (k, float(v))
                                for k, v in (comp_hints or {}).items())})
        hit = cache.get(key)
        if hit is not None:
            plan = Plan.from_dict(hit)
    if plan is None:
        plan = search_period(base, fabric=fabric, backend=backend,
                             x_shape=tuple(x_shape),
                             weight_shapes=weight_shapes,
                             dtype_bytes=dtype_bytes,
                             mb_candidates=mb_candidates,
                             comp_hints=comp_hints)
        if cache is not None and key is not None:
            cache.put(key, plan.to_dict())
    fallback = PerfsimPlanner(
        value_shapes=microbatch_value_shapes(x_shape,
                                            plan.num_microbatches),
        weight_shapes=weight_shapes, dtype_bytes=dtype_bytes,
        fabric=fabric, backend=backend,
        num_microbatches=plan.num_microbatches,
        comp_hints=microbatch_comp_hints(comp_hints,
                                         plan.num_microbatches))
    return plan, FixedPairing(plan, fallback)


class PerfsimPlanner:
    """A pass-3 planner object for :func:`repro.core.dataflow.optimize`.

    ``pair(g2)`` looks the (graph, shapes, topology, backend) key up in the
    plan cache, otherwise runs :func:`search_pairing`, persists the result,
    and applies the winning pairing via ``pair_asymmetric(g2, pairing=...)``.
    The last decision is kept on ``self.plan`` for observability. Shapes
    default to :func:`repro.plan.lower.synthesize_shapes` when the caller
    has none (the bare ``optimize(g, planner="perfsim")`` form)."""

    def __init__(self, value_shapes: Optional[Dict[str, tuple]] = None,
                 weight_shapes: Optional[Dict[str, tuple]] = None,
                 dtype_bytes: int = 4,
                 fabric: Optional[Fabric] = None,
                 backend: str = "cais",
                 num_microbatches: int = 1,
                 chunk_candidates: Sequence[Optional[int]] =
                 CHUNK_CANDIDATES,
                 branch: int = 3, max_states: int = 64,
                 cache: Optional[cache_mod.PlanCache] = None,
                 comp_hints: Optional[Dict[str, float]] = None):
        self.value_shapes = value_shapes
        self.weight_shapes = weight_shapes
        self.dtype_bytes = dtype_bytes
        self.fabric = fabric or Fabric()
        self.backend = backend
        self.num_microbatches = num_microbatches
        self.chunk_candidates = tuple(chunk_candidates)
        self.branch = branch
        self.max_states = max_states
        self.cache = cache
        self.comp_hints = dict(comp_hints) if comp_hints else None
        self.plan: Optional[Plan] = None

    def _shapes(self, g2: df.Graph):
        vs, ws = lower_mod.synthesize_shapes(g2)
        return ({**vs, **(self.value_shapes or {})},
                {**ws, **(self.weight_shapes or {})})

    def pair(self, g2: df.Graph) -> df.Graph:
        value_shapes, weight_shapes = self._shapes(g2)
        key = None
        if self.cache is not None:
            key = cache_mod.plan_key(
                g2, value_shapes, weight_shapes, self.dtype_bytes,
                self.fabric, self.backend,
                extra={"chunks": [c for c in self.chunk_candidates if c],
                       "branch": self.branch,
                       "max_states": self.max_states,
                       "hints": sorted(
                           (k, float(v))
                           for k, v in (self.comp_hints or {}).items())})
            hit = self.cache.get(key)
            if hit is not None:
                plan = Plan.from_dict(hit)
                try:
                    out = df.pair_asymmetric(g2, pairing=plan.pairing)
                except df.GraphError:
                    pass        # stale plan (graph changed) → re-search
                else:
                    self.plan = plan
                    return out
        plan = search_pairing(
            g2, fabric=self.fabric, backend=self.backend,
            value_shapes=value_shapes, weight_shapes=weight_shapes,
            dtype_bytes=self.dtype_bytes,
            num_microbatches=self.num_microbatches,
            chunk_candidates=self.chunk_candidates,
            branch=self.branch, max_states=self.max_states,
            comp_hints=self.comp_hints)
        if self.cache is not None and key is not None:
            self.cache.put(key, plan.to_dict())
        self.plan = plan
        return df.pair_asymmetric(g2, pairing=plan.pairing)
