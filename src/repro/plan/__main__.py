"""Planner smoke: ``python -m repro.plan --selfcheck``.

Device-free tier-1 CI gate: lowers the canonical sublayer graphs, runs the
pairing search (planner makespan must be ≤ greedy's), and round-trips a plan
through the cache (second call must be a hit returning the identical plan).
``--calibrate PATH`` additionally fits the fabric from a bench JSON and
reports the per-cell simulated/measured ratios.
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def selfcheck() -> int:
    from repro.core import dataflow as df
    from repro.core.perfsim import Fabric
    from repro.plan import (PerfsimPlanner, PlanCache, search_period,
                            simulate, policy_for_backend)

    fabric = Fabric(n=8)

    # 1. lower: the optimized sublayer graph costs out under both backends
    g = df.optimize(df.sublayer_graph())
    for backend in ("barrier", "cais"):
        m = simulate(g, fabric, policy_for_backend(backend))
        assert m > 0, f"sublayer lowering produced empty makespan ({backend})"
        print(f"selfcheck: lower sublayer [{backend}] makespan={m:.3e}s")

    # 2. search: on the dual-sublayer graph the planner's simulated makespan
    # must not exceed the greedy pass-3 schedule's
    g2 = df.fuse_sublayer_chain(df.fuse_shared_gather(
        df.fuse_compute_aware(df.dual_sublayer_graph())))
    planner = PerfsimPlanner(fabric=fabric, backend="cais")
    planner.pair(g2)
    p = planner.plan
    assert p is not None and p.makespan <= p.greedy_makespan + 1e-12, \
        f"planner ({p.makespan}) worse than greedy ({p.greedy_makespan})"
    print(f"selfcheck: search dual-sublayer planner={p.makespan:.3e}s "
          f"greedy={p.greedy_makespan:.3e}s pairing={list(p.pairing)}")

    # 3. period search: a 2-chain microbatch split of the sublayer period
    plan = search_period(df.sublayer_graph(), fabric=fabric, backend="cais",
                         x_shape=(8, 512, 1024),
                         weight_shapes={"w1": (1024, 1024),
                                        "w2": (1024, 1024),
                                        "scale": (1024,)},
                         mb_candidates=(1, 2))
    assert plan.makespan <= plan.greedy_makespan + 1e-12
    print(f"selfcheck: period search mb={plan.num_microbatches} "
          f"chunks={plan.num_chunks} makespan={plan.makespan:.3e}s")

    # 4. cache round-trip: miss → put → hit with the identical plan
    with tempfile.TemporaryDirectory() as td:
        cache = PlanCache(root=td)
        pl1 = PerfsimPlanner(fabric=fabric, backend="cais", cache=cache)
        ga = pl1.pair(g2)
        pl2 = PerfsimPlanner(fabric=fabric, backend="cais", cache=cache)
        gb = pl2.pair(g2)
        assert cache.stats == {"hits": 1, "misses": 1}, cache.stats
        assert pl1.plan == pl2.plan, "cache hit returned a different plan"
        assert [n.name for n in ga.nodes] == [n.name for n in gb.nodes]
        print(f"selfcheck: cache round-trip stats={cache.stats}")

    print("selfcheck: OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.plan")
    ap.add_argument("--selfcheck", action="store_true",
                    help="lower → search → cache round-trip, no devices")
    ap.add_argument("--calibrate", metavar="BENCH_JSON",
                    help="fit fabric parameters from a bench JSON")
    args = ap.parse_args(argv)
    rc = 0
    if args.selfcheck:
        rc = selfcheck()
    if args.calibrate:
        from repro.plan import RATIO_TOLERANCE, calibrate
        res = calibrate(args.calibrate)
        for cell, r in sorted(res.ratios.items()):
            print(f"calibrate: {cell} simulated/measured={r:.3f}")
        print(f"calibrate: fitted bw={res.fabric.bw:.3e} "
              f"alpha={res.fabric.alpha:.3e} "
              f"mxu_eff={res.fabric.mxu_eff:.3e}"
              f" max|ln ratio|={res.max_abs_log_ratio:.3f} "
              f"(tolerance {RATIO_TOLERANCE})")
        rc = rc or (0 if res.within_tolerance else 1)
    if not args.selfcheck and not args.calibrate:
        ap.print_help()
        return 2
    return rc


if __name__ == "__main__":
    sys.exit(main())
