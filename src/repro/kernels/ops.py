"""Jit'd public wrappers for the Pallas kernels.

On a real TPU runtime (`jax.default_backend() == "tpu"`) the kernels lower
natively; everywhere else they run under ``interpret=True`` (the Python
interpreter executes the kernel body — correctness validation on CPU, per
the assignment). The models use the pure-jnp paths by default and switch to
these via ``Runtime`` flags on TPU (interpret-mode kernels inside a 32k-token
graph would unroll the grid into the HLO).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import matmul_ln as _ml


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype"))
def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
           out_dtype=None):
    return _mm.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret(),
                      out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "eps", "out_dtype"))
def matmul_rmsnorm(a, b, scale, *, bm: int = 128, bk: int = 512,
                   eps: float = 1e-6, out_dtype=None):
    return _ml.matmul_rmsnorm(a, b, scale, bm=bm, bk=bk, eps=eps,
                              interpret=_interpret(), out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bkv: int = 256, scale=None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                               scale=scale, interpret=_interpret())
