"""Blocked MXU matmul Pallas kernel (TPU target; validated interpret=True).

Tiling: grid (M/bm, N/bn, K/bk) with (bm, bk)·(bk, bn) tiles staged in VMEM
and a float32 VMEM accumulator — MXU-aligned block shapes (multiples of the
128×128 systolic tile; bf16 inputs accumulate in f32 as the MXU does).
This is the partial-GEMM building block the CAIS ring schedules consume
(one call per arriving activation chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += a_tile @ b_tile; flush at last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_divisor(dim: int, want: int) -> int:
    b = max(1, min(dim, want))
    while dim % b:
        b //= 2
    return max(b, 1)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 512, interpret: bool = True, out_dtype=None):
    """a: (M, K) @ b: (K, N) -> (M, N). Block sizes are clipped to divisors
    of the problem shape; defaults keep the VMEM working set
    (bm·bk + bk·bn tiles bf16 + bm·bn f32 accumulator ≈ 0.5 MB) well under
    the ~128 MB/core budget while filling the MXU (≥128 in every dim)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = (block_divisor(M, bm), block_divisor(N, bn),
                  block_divisor(K, bk))
    n_k = K // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
