"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def matmul_rmsnorm_ref(a, b, scale, eps: float = 1e-6, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    z = jnp.dot(a, b, preferred_element_type=jnp.float32)
    var = jnp.mean(z * z, axis=-1, keepdims=True)
    zn = z * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return zn.astype(out_dtype)


def flash_attention_ref(q, k, v, causal: bool = True, scale=None):
    """q,k,v: (BH, S, d) — naive softmax attention in f32."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
