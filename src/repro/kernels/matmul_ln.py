"""Fused GEMM + RMSNorm-epilogue Pallas kernel (TPU target).

The paper's L1–L4 sub-layers chain GEMM → LN → GEMM; in the CAIS pipeline the
LN runs sequence-parallel on the reduce-scattered shard. This kernel fuses
the normalization into the producing GEMM's epilogue so the normalized
activation never round-trips to HBM.

Tiling: grid (M/bm, K/bk) with the FULL N dimension resident per block
(norm needs the whole feature row; bm·N f32 ≈ 128·8192·4 = 4 MB — fits
VMEM for every assigned arch's d_model/d_ff).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.matmul import block_divisor


def _matmul_ln_kernel(a_ref, b_ref, scale_ref, o_ref, acc_ref, *,
                      n_k: int, eps: float):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        z = acc_ref[...]                                    # (bm, N) f32
        var = jnp.mean(z * z, axis=-1, keepdims=True)
        zn = z * jax.lax.rsqrt(var + eps)
        zn = zn * (1.0 + scale_ref[...].astype(jnp.float32))
        o_ref[...] = zn.astype(o_ref.dtype)


def matmul_rmsnorm(a: jnp.ndarray, b: jnp.ndarray, scale: jnp.ndarray, *,
                   bm: int = 128, bk: int = 512, eps: float = 1e-6,
                   interpret: bool = True, out_dtype=None):
    """rmsnorm(a @ b) * (1 + scale). a: (M, K); b: (K, N); scale: (N,)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and scale.shape == (N,)
    out_dtype = out_dtype or a.dtype
    bm, bk = block_divisor(M, bm), block_divisor(K, bk)
    n_k = K // bk

    return pl.pallas_call(
        functools.partial(_matmul_ln_kernel, n_k=n_k, eps=eps),
        grid=(M // bm, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
            pl.BlockSpec((N,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
        interpret=interpret,
    )(a, b, scale)
