"""Blocked causal flash attention Pallas kernel (TPU target).

Online-softmax over KV blocks with running (m, l, o) state in VMEM — the
compute hot-spot of the 32k prefill shapes. Grid: (batch·heads, Sq/bq); the
kv loop is the innermost grid dimension so K/V tiles stream HBM→VMEM while
the (bq, d) accumulator stays resident. Causal masking skips fully-masked
KV blocks via the block index comparison (the mask never materializes at
(S, S)).

Supports GQA by folding the query-group into the batch·heads grid axis
(callers pass q heads with their kv head's K/V).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, bq: int, bkv: int, scale: float, causal: bool):
    qi = pl.program_id(1)   # query block index
    ki = pl.program_id(2)   # kv block index

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0]                                  # (bq, d)
        k = k_ref[0]                                  # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1)[:, None]          # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # skip kv blocks strictly above the diagonal
        @pl.when(ki * bkv <= qi * bq + bq - 1)
        def _():
            body()
    else:
        body()

    @pl.when(ki == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 256, bkv: int = 256,
                    scale=None, interpret: bool = True):
    """q, k, v: (BH, S, d) — batch and heads pre-folded. Returns (BH, S, d).

    VMEM working set per step: q(bq·d) + k,v(bkv·d) + acc(bq·d f32)
    ≈ 0.7 MB at defaults with d=128."""
    BH, Sq, d = q.shape
    _, Skv, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    from repro.kernels.matmul import block_divisor
    bq = block_divisor(Sq, bq)
    bkv = block_divisor(Skv, bkv)
    n_kv = Skv // bkv

    return pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=n_kv, bq=bq, bkv=bkv,
                          scale=scale, causal=causal),
        grid=(BH, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
