from repro.train.step import init_state, make_decode_step, make_prefill_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["init_state", "make_train_step", "make_prefill_step",
           "make_decode_step", "Trainer", "TrainerConfig"]
