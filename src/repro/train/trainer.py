"""Trainer: the production step loop — checkpoint/restart, straggler
watchdog, heartbeat, preemption handling, deterministic resume.

Restart semantics: the data pipeline is keyed on (seed, step), so
restore(step) + iterate(start_step=step) replays the exact stream; loss
curves across a kill/restart are bitwise-continuable (tested in
tests/test_substrate.py::test_checkpoint_restart_determinism).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro import sharding
from repro.checkpoint import store
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.watchdog import Heartbeat, PreemptionGuard, StepWatchdog
from repro.optim.optimizers import Optimizer
from repro.runtime import Runtime
from repro.train.step import init_state, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    straggler_threshold: float = 2.5
    heartbeat_path: Optional[str] = None


@dataclass
class Trainer:
    model: Any
    opt: Optimizer
    arch: ArchConfig
    shape: ShapeConfig
    rt: Runtime = Runtime()
    cfg: TrainerConfig = field(default_factory=TrainerConfig)
    data: DataConfig = field(default_factory=DataConfig)
    mesh: Any = None
    # injectable for tests: step-time override to simulate stragglers
    _clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.train_step = jax.jit(
            make_train_step(self.model, self.opt, self.rt,
                            self.cfg.microbatches))
        self.watchdog = StepWatchdog(threshold=self.cfg.straggler_threshold)
        self.history: List[Dict[str, float]] = []
        self.events: List[str] = []

    # ----- state -----
    def fresh_state(self, seed: int = 0):
        return init_state(self.model, self.opt, jax.random.key(seed))

    def restore_or_init(self, seed: int = 0):
        if self.cfg.ckpt_dir and store.latest_step(self.cfg.ckpt_dir) is not None:
            template = jax.eval_shape(self.fresh_state, seed)
            state, manifest = store.restore(self.cfg.ckpt_dir, template)
            self.events.append(f"restored step {manifest['step']}")
            return state
        return self.fresh_state(seed)

    # ----- loop -----
    def run(self, state=None, seed: int = 0):
        state = state if state is not None else self.restore_or_init(seed)
        hb = None
        if self.cfg.heartbeat_path:
            hb = Heartbeat(self.cfg.heartbeat_path)
            hb.start()
        saver = store.AsyncSaver(self.cfg.ckpt_dir) if self.cfg.ckpt_dir \
            else None
        try:
            with PreemptionGuard() as guard, \
                    sharding.use_mesh(self.mesh):
                start = int(state["step"])
                for step in range(start, self.cfg.total_steps):
                    t0 = self._clock()
                    batch = make_batch(self.arch, self.shape, step, self.data)
                    state, metrics = self.train_step(state, batch)
                    loss = float(metrics["loss"])
                    dt = self._clock() - t0

                    if self.watchdog.observe(step, dt):
                        self.events.append(f"straggler@{step}")
                        # policy: checkpoint now so an orchestrator can
                        # restart on healthy hosts without losing work
                        if saver:
                            saver.save(state, step + 1)
                        self.watchdog.reset()

                    self.history.append(
                        {"step": step, "loss": loss, "dt": dt})
                    if step % self.cfg.log_every == 0:
                        print(f"step {step:6d} loss {loss:8.4f} "
                              f"dt {dt*1e3:7.1f}ms")
                    if saver and (step + 1) % self.cfg.ckpt_every == 0:
                        saver.save(state, step + 1)
                    if guard.requested:
                        self.events.append(f"preempted@{step}")
                        if saver:
                            saver.save(state, step + 1)
                        break
        finally:
            if saver:
                saver.wait()
            if hb:
                hb.stop()
        return state
