"""train_step / serve-step builders: the jit-compiled units the launcher,
dry-run, trainer, and benchmarks all share.

`make_train_step(model, opt, rt)` returns `(state, batch) -> (state,
metrics)` with optional microbatch gradient accumulation (a `lax.scan` over
microbatches — constant memory at any global batch). State pytree:
{"params", "opt", "step"}.

Gradients flow through `jax.value_and_grad` as usual; when
``rt.tp.graph_backward`` is on (the default) the dense-period portion of
that backward is NOT plain autodiff — ``sp_period`` carries a custom VJP
whose backward is itself a dataflow graph lowered through ``optimize() →
execute()`` (docs/training.md), so pass 3 can pair forward and backward
collectives across microbatch chains. This composes with ``rt.remat``:
``jax.checkpoint`` replays the period forward and then invokes the same
graph-built backward.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.optim.optimizers import Optimizer, global_norm
from repro.runtime import Runtime

State = Dict[str, Any]


def init_state(model, opt: Optimizer, key) -> State:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(model, opt: Optimizer, rt: Runtime,
                    microbatches: int = 1):
    """Build the jit-able train step (grad accumulation over microbatches)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: State, batch) -> Tuple[State, Dict[str, Any]]:
        params = state["params"]
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                l, g = grads_of(params, mb)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_grads, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt = opt.apply(params, grads, state["opt"],
                                        state["step"])
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": global_norm(grads),
            "step": state["step"],
        }
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_prefill_step(model, rt: Runtime, s_max: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max=s_max)
    return prefill_step


def make_decode_step(model, rt: Runtime):
    def decode_step(params, token, caches, idx):
        return model.decode_step(params, token, caches, idx)
    return decode_step
