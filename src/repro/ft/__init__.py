from repro.ft.watchdog import Heartbeat, PreemptionGuard, StepWatchdog

__all__ = ["StepWatchdog", "Heartbeat", "PreemptionGuard"]
