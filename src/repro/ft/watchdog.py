"""Fault-tolerance hooks: straggler watchdog, heartbeats, preemption.

TPU SPMD has no per-step partial recovery — the production policy is
detect → checkpoint → restart (possibly on a smaller/different mesh, see
checkpoint.restore's resharding). This module supplies the detection and
policy layer the Trainer drives:

  * StepWatchdog   — EWMA of step times; flags persistent stragglers
                     (paper-adjacent: the same temporal-skew problem CAIS's
                     TB coordination solves at µs scale appears at cluster
                     scale as slow hosts).
  * Heartbeat      — liveness file another process/orchestrator can watch;
                     missing beats ⇒ the job is hung ⇒ external restart.
  * PreemptionGuard— converts SIGTERM into a "save-and-exit-clean" request.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StepWatchdog:
    """Flags a straggler when step time exceeds `threshold` × EWMA for
    `patience` consecutive steps."""

    threshold: float = 2.0
    patience: int = 3
    alpha: float = 0.1
    ewma: Optional[float] = None
    strikes: int = 0
    flagged_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when a persistent straggler is detected."""
        if self.ewma is None:
            self.ewma = dt
            return False
        is_slow = dt > self.threshold * self.ewma
        if is_slow:
            self.strikes += 1
            self.flagged_steps.append(step)
        else:
            self.strikes = 0
            # only fold healthy steps into the EWMA (stragglers would mask
            # themselves by inflating the baseline)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return self.strikes >= self.patience

    def reset(self):
        self.strikes = 0


class Heartbeat:
    """Writes a monotonic beat to a file every `interval` seconds from a
    daemon thread; orchestrators restart the job when the file goes stale."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self):
        n = 0
        while not self._stop.wait(self.interval):
            n += 1
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{n} {time.time()}")
            os.replace(tmp, self.path)

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)


class PreemptionGuard:
    """SIGTERM/SIGINT → set a flag the trainer polls each step; the trainer
    checkpoints and exits cleanly instead of dying mid-step."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False
