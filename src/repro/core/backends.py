"""CollectiveBackend: the registry-dispatched TP execution API.

Every tensor-parallel collective-fused schedule the model path can run —
AG→GEMM, GEMM→RS, GEMM→AR, the expert all-to-all, the fused RS+LN+AG
sub-layer chain (and its gather-less RS+LN prefix for the MoE router seam),
and the asymmetric dual-stream overlap — is reached through
one seam: a :class:`CollectiveBackend` instance looked up by name in a
process-global registry. ``repro.core.tp`` and ``repro.core.dataflow.execute``
dispatch through the backend instead of branching on mode strings, so adding
a new communication strategy is *one registration*, not an edit of every
sub-layer.

Built-in backends
-----------------
``barrier``
    The NVLS-style communication-centric baseline: one monolithic collective
    HLO op (all-gather / reduce-scatter / all-to-all) around each GEMM.
``cais``
    The paper's compute-aware decomposed schedules
    (:mod:`repro.core.primitives`): ring ``collective_permute`` chains
    interleaved with partial GEMMs. When ``CAISConfig.num_chunks`` is None
    the backend is *compute-aware in the paper's §III-B sense*: it picks the
    chunking per collective from the payload bytes and ring size via
    :func:`repro.core.coordination.plan` (cached per shape); an explicit
    integer in the config is honored as a static override.
``auto``
    Reference backend that defers scheduling to XLA. Its methods are the
    plain monolithic formulations (identical math to ``barrier``), and it
    reports ``explicit = False``: the model path skips ``shard_map`` entirely
    and lets the compiler place collectives from sharding constraints.

Registration API
----------------
::

    from repro.core.backends import CollectiveBackend, register_backend

    class MyBackend(CollectiveBackend):
        name = "mine"
        def ag_gemm_multi(self, x, ws, axis, cais): ...
        ...

    register_backend(MyBackend())   # now TPConfig(mode="mine") works
    get_backend("mine")                    # -> the instance
    available_backends()                   # -> ["auto", "barrier", "cais", "mine"]

All methods run INSIDE ``shard_map`` (they may use ``lax.axis_index`` /
``lax.ppermute``); ``repro.core.tp`` owns the pjit-callable wrapping.

``docs/backends.md`` is the authoring guide: which methods are mandatory vs
composed by default from the backend's own primitives (``gemm_ar``,
``fused_rs_ln``, ``fused_rs_ln_ag[_multi]``), with ``barrier``/``cais`` as
the worked examples.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import coordination
from repro.core import primitives as prim
from repro.core.primitives import CAISConfig


class CollectiveBackend:
    """Protocol for TP collective-fused execution strategies.

    Subclasses implement the seven schedule methods below; ``name`` is the
    registry key and ``explicit`` says whether the model path should enter
    ``shard_map`` for this backend (False = XLA-scheduled reference).
    Method contracts match :mod:`repro.core.primitives` (same shapes and
    layouts; see the module docstring there).
    """

    name: str = "abstract"
    explicit: bool = True

    # -- AG-aligned -------------------------------------------------------
    def ag_gemm(self, x, w, axis: str, cais: CAISConfig) -> jnp.ndarray:
        """(B, S_loc, d) seq-sharded x; (d, F_loc) w -> (B, S, F_loc)."""
        return self.ag_gemm_multi(x, (w,), axis, cais)[0]

    def ag_gemm_multi(self, x, ws: Sequence, axis: str,
                      cais: CAISConfig) -> Tuple[jnp.ndarray, ...]:
        """One gather shared by several column-sharded weights (QKV, up+gate)."""
        raise NotImplementedError

    # -- RS/AR-aligned ----------------------------------------------------
    def gemm_rs(self, x, w, axis: str, cais: CAISConfig) -> jnp.ndarray:
        """(B, S, d_loc) feat-sharded x; (d_loc, F) w -> (B, S_loc, F)."""
        raise NotImplementedError

    def gemm_ar(self, x, w, axis: str, cais: CAISConfig) -> jnp.ndarray:
        """(B, S, d_loc) feat-sharded x; (d_loc, F) w -> (B, S, F) reduced.
        Default: AR = RS + AG composed from the backend's own ``gemm_rs``
        (the decode/ragged-S dense schedule works on any backend that
        implements the RS side). Falls back to a monolithic allreduce when
        the sequence cannot scatter over the ring (S % n != 0, e.g. S=1)."""
        if self.hierarchical(axis):
            return self.hier_gemm_ar(x, w, axis, cais)
        n = prim._axis_size(axis) if cais.interpret_n is None \
            else cais.interpret_n
        if int(x.shape[1]) % max(n, 1) != 0:
            return prim.barrier_gemm_ar(x, w, axis)
        y = self.gemm_rs(x, w, axis, cais)
        return lax.all_gather(y, axis, axis=1, tiled=True)

    # -- EP ---------------------------------------------------------------
    def a2a_expert_ffn(self, send, ffn: Callable, axis: str,
                       cais: CAISConfig) -> jnp.ndarray:
        """(n, C, d) routed chunks -> (n, C, d) expert outputs (see prim)."""
        raise NotImplementedError

    # -- fused sub-layer chain -------------------------------------------
    def fused_rs_ln_ag(self, x, w1, ln_scale, w2, axis: str, cais: CAISConfig,
                       norm: str = "rmsnorm", residual=None):
        """GEMM-RS -> (+res) -> LN -> AG-GEMM. Returns (out, z). Default:
        composed from the backend's own ``gemm_rs`` / ``ag_gemm``, so custom
        backends get the fused seam for free (non-gated blocks fuse to this
        single-weight form)."""
        outs, z = self.fused_rs_ln_ag_multi(x, w1, ln_scale, (w2,), axis,
                                            cais, norm=norm,
                                            residual=residual)
        return outs[0], z

    def fused_rs_ln_ag_multi(self, x, w1, ln_scale, ws2: Sequence, axis: str,
                             cais: CAISConfig, norm: str = "rmsnorm",
                             residual=None):
        """GEMM-RS -> (+res) -> LN -> shared-gather AG-GEMM against several
        weights (the whole-block attention-out → gated-FFN-in seam).
        Returns (per-weight outputs tuple, z). Default: composed from the
        backend's own ``gemm_rs`` / ``ag_gemm_multi``, so custom backends
        get the fused seam for free."""
        from repro.models.layers import apply_norm

        z = self.gemm_rs(x, w1, axis, cais)
        if residual is not None:
            z = z + residual
        zn = apply_norm(norm, {"scale": ln_scale}, z)
        return self.ag_gemm_multi(zn, tuple(ws2), axis, cais), z

    def fused_rs_ln(self, x, w1, ln_scale, axis: str, cais: CAISConfig,
                    norm: str = "rmsnorm", residual=None):
        """GEMM-RS -> (+res) -> LN with no trailing gather — the MoE
        attention-out → router seam (the next collective is the expert
        all-to-all). Returns (normed, z). Default: composed from the
        backend's own ``gemm_rs``, so custom backends get it for free."""
        from repro.models.layers import apply_norm

        z = self.gemm_rs(x, w1, axis, cais)
        if residual is not None:
            z = z + residual
        return apply_norm(norm, {"scale": ln_scale}, z), z

    # -- backward collectives (training graphs, docs/training.md) ---------
    def grad_ag_gemm(self, d, wT, axis: str, cais: CAISConfig):
        """Adjoint of ``gemm_rs`` (the ``bwd_ag_gemm`` IR op): all-gather the
        seq-sharded output cotangent ``d`` (B, S_loc, F) and GEMM it with the
        transposed local weight shard ``wT`` (F, d_loc). Returns
        ``(d @ wT gathered, d gathered)`` — the second output feeds the
        weight-gradient GEMM, so the gather runs once. Default: one
        monolithic all-gather (the barrier schedule)."""
        if self.hierarchical(axis):
            return self.hier_grad_ag_gemm(d, wT, axis, cais)
        g = lax.all_gather(d, axis, axis=1, tiled=True)
        return g @ wT, g

    def grad_a2a_expert_ffn(self, send, gy, bwd_row: Callable, axis: str,
                            cais: CAISConfig):
        """Adjoint of ``a2a_expert_ffn`` (the ``bwd_a2a_ffn`` IR op):
        re-run the dispatch all-to-all for ``send`` AND for the output
        cotangent ``gy`` (Megatron-style recompute — the forward's routed
        chunks are not stashed), apply the per-row expert VJP
        ``bwd_row(chunk, gy_row) -> (d_chunk, dw_tuple)`` at the owning
        device, then reverse-a2a the chunk cotangents back to their
        senders. Expert weight grads stay LOCAL at the owner (summed over
        the arriving rows) — EP weight gradients never ride a collective.
        Default: monolithic all-to-alls (the barrier schedule)."""
        if self.hierarchical(axis):
            return self.hier_grad_a2a_expert_ffn(send, gy, bwd_row, axis,
                                                 cais)
        n = prim._axis_size(axis) if cais.interpret_n is None \
            else cais.interpret_n
        if n == 1:
            d_rows, dw_rows = jax.vmap(bwd_row)(send, gy)
            return d_rows, tuple(jnp.sum(a, axis=0) for a in dw_rows)

        def a2a(t):
            return lax.all_to_all(t, axis, split_axis=0, concat_axis=0,
                                  tiled=False)

        recv = a2a(send)
        gyr = a2a(gy)
        d_rows, dw_rows = jax.vmap(bwd_row)(recv, gyr)
        return a2a(d_rows), tuple(jnp.sum(a, axis=0) for a in dw_rows)

    # -- asymmetric dual-stream overlap ----------------------------------
    def overlap_asymmetric(self, rs_args, ag_args, axis: str,
                           cais: CAISConfig):
        """Independent GEMM-RS + AG-GEMM pair; the AG side's weight may be a
        tuple (paired ``ag_gemm_multi``). Returns (rs_out, ag_out[s])."""
        raise NotImplementedError

    # -- hierarchical (2D-mesh) compositions ------------------------------
    # ``axis`` may be the composite ``("tp_in", "tp_out")`` tuple from
    # ``sharding.tp_axes`` (tp_in MAJOR in the flattened shard order, so the
    # slow axis's shard index is minor). Concrete methods dispatch here for
    # tuple axes; the compositions run the inter-node legs through the
    # ``_outer_*`` hooks (monolithic by default, ring-decomposed with
    # inter-tier chunk planning on cais) and reuse the backend's OWN fused
    # schedules on the fast intra-node ring — custom backends become
    # 2D-capable without new code. docs/topology.md derives the orderings:
    # AG gathers inter-node first (minor index → contiguous intra blocks),
    # RS scatters intra-node first.

    @staticmethod
    def hierarchical(axis) -> bool:
        """True when ``axis`` is a composite (2D-mesh) axis tuple."""
        return isinstance(axis, (tuple, list)) and len(axis) > 1

    def _inner_all_gather(self, x, axis: str, cais: CAISConfig):
        """Intra-node all-gather leg (dim 1) of hierarchical AR."""
        return lax.all_gather(x, axis, axis=1, tiled=True)

    def _outer_all_gather(self, x, axis: str, cais: CAISConfig):
        """Inter-node all-gather leg (dim 1)."""
        return lax.all_gather(x, axis, axis=1, tiled=True)

    def _outer_reduce_scatter(self, x, axis: str, cais: CAISConfig):
        """Inter-node reduce-scatter leg (dim 1)."""
        return lax.psum_scatter(x, axis, scatter_dimension=1, tiled=True)

    def hier_ag_gemm_multi(self, x, ws, axis, cais: CAISConfig):
        """AG→GEMM on the composite axis: gather the slow inter-node axis
        first (its shard index is minor, so the concat yields this node's
        contiguous block), then the backend's fused schedule on tp_in."""
        xg = self._outer_all_gather(x, axis[-1], cais)
        return self.ag_gemm_multi(xg, tuple(ws), axis[0], cais)

    def hier_gemm_rs(self, x, w, axis, cais: CAISConfig):
        """GEMM→RS on the composite axis: the backend's fused intra-node
        reduce-scatter first (tp_in-major shard order), then the inter-node
        exchange on 1/tp_in of the payload."""
        y = self.gemm_rs(x, w, axis[0], cais)
        return self._outer_reduce_scatter(y, axis[-1], cais)

    def hier_gemm_ar(self, x, w, axis, cais: CAISConfig):
        """GEMM→AR: intra-node reduce-scatter → inter-node exchange →
        all-gather back out through both tiers (the classic hierarchical
        AR). Ragged sequences that cannot scatter over the full composite
        ring fall back to the monolithic allreduce — ``lax.psum`` takes the
        axis tuple directly."""
        axes = tuple(axis)
        if int(x.shape[1]) % max(prim._axis_size(axes), 1) != 0:
            return prim.barrier_gemm_ar(x, w, axes)
        y = self.hier_gemm_rs(x, w, axis, cais)
        y = self._outer_all_gather(y, axis[-1], cais)
        return self._inner_all_gather(y, axis[0], cais)

    def hier_a2a_expert_ffn(self, send, ffn: Callable, axis,
                            cais: CAISConfig):
        """Grouped-EP expert all-to-all: experts replicate across ``tp_in``
        and shard over ``tp_out`` only, so the dispatch/combine traffic
        never crosses the intra-node ring (``send`` is (tp_out, C, d))."""
        return self.a2a_expert_ffn(send, ffn, axis[-1], cais)

    def hier_grad_ag_gemm(self, d, wT, axis, cais: CAISConfig):
        """Adjoint gather through both tiers: inter-node first, intra-node
        second (same ordering as the forward hierarchical AG)."""
        g = self._outer_all_gather(d, axis[-1], cais)
        g = self._inner_all_gather(g, axis[0], cais)
        return g @ wT, g

    def hier_grad_a2a_expert_ffn(self, send, gy, bwd_row, axis,
                                 cais: CAISConfig):
        """Grouped-EP adjoint: exactly like the forward, the grad
        dispatch/combine traffic runs on ``tp_out`` only — grouped-EP
        gradients never cross the fast intra-node ring (experts replicate
        across ``tp_in``; the per-owner dw sums are completed by the
        training wrapper's weight-grad psum over ``tp_in``)."""
        return self.grad_a2a_expert_ffn(send, gy, bwd_row, axis[-1], cais)

    def hier_overlap_asymmetric(self, rs_args, ag_args, axis,
                                cais: CAISConfig):
        """The lockstep dual-stream schedule is a single-ring construct; on
        2D meshes the two sides run as their hierarchical compositions (the
        intra-node legs still overlap under the compiler's scheduler; the
        inter-node legs serialize)."""
        x_rs, w_rs = rs_args
        x_ag, w_ag = ag_args
        rs_out = self.gemm_rs(x_rs, w_rs, axis, cais)
        multi = isinstance(w_ag, (tuple, list))
        ag_out = self.ag_gemm_multi(x_ag,
                                    tuple(w_ag) if multi else (w_ag,),
                                    axis, cais)
        return rs_out, (ag_out if multi else ag_out[0])


# ---------------------------------------------------------------------------
# barrier — monolithic NVLS-style collectives around each GEMM
# ---------------------------------------------------------------------------


class BarrierBackend(CollectiveBackend):
    """Communication-centric baseline: opaque collective phases. On 2D
    meshes the AG/RS sides compose hierarchically from monolithic per-axis
    legs; ``gemm_ar`` stays ONE opaque allreduce (``lax.psum`` accepts the
    composite axis tuple) — the baseline's defining phase structure."""

    name = "barrier"

    def ag_gemm_multi(self, x, ws, axis, cais):
        if self.hierarchical(axis):
            return self.hier_ag_gemm_multi(x, ws, axis, cais)
        xg = lax.all_gather(x, axis, axis=1, tiled=True)
        return tuple(xg @ w for w in ws)

    def gemm_rs(self, x, w, axis, cais):
        if self.hierarchical(axis):
            return self.hier_gemm_rs(x, w, axis, cais)
        return prim.barrier_gemm_rs(x, w, axis)

    def gemm_ar(self, x, w, axis, cais):
        return prim.barrier_gemm_ar(
            x, w, tuple(axis) if self.hierarchical(axis) else axis)

    def a2a_expert_ffn(self, send, ffn, axis, cais):
        if self.hierarchical(axis):
            return self.hier_a2a_expert_ffn(send, ffn, axis, cais)
        return prim.barrier_a2a_expert_ffn(send, ffn, axis)

    def overlap_asymmetric(self, rs_args, ag_args, axis, cais):
        if self.hierarchical(axis):
            return self.hier_overlap_asymmetric(rs_args, ag_args, axis, cais)
        x_rs, w_rs = rs_args
        x_ag, w_ag = ag_args
        rs_out = prim.barrier_gemm_rs(x_rs, w_rs, axis)
        if isinstance(w_ag, (tuple, list)):
            return rs_out, self.ag_gemm_multi(x_ag, tuple(w_ag), axis, cais)
        return rs_out, prim.barrier_ag_gemm(x_ag, w_ag, axis)


# ---------------------------------------------------------------------------
# cais — decomposed ring schedules with compute-aware chunk planning
# ---------------------------------------------------------------------------


@lru_cache(maxsize=512)
def _planned_chunks(payload_bytes: int, ring: int, bidirectional: bool,
                    hw=None) -> int:
    """coordination.plan() keyed per (payload, ring, hw) — shapes are static
    under jit so the cache collapses repeated traces to one planner call.
    ``hw`` is the α-β tier being planned (None → V5E); hierarchical legs
    pass the inter-node tier here so the slow axis is never planned against
    the intra-node bandwidth."""
    return coordination.plan(float(payload_bytes), ring,
                             bidirectional=bidirectional,
                             hw=hw or coordination.V5E).num_chunks


class CAISBackend(CollectiveBackend):
    """The paper's technique: permute chains interleaved with partial GEMMs,
    chunked per-collective by the coordination planner unless the caller
    pins ``CAISConfig.num_chunks``."""

    name = "cais"

    @staticmethod
    def plan_chunks(payload_bytes: float, ring: int,
                    bidirectional: bool = True, hw=None) -> int:
        """The chunking the backend would auto-pick for this collective
        (``hw=None`` → V5E; pass ``hw.inter_tier()`` for inter-node legs)."""
        return _planned_chunks(int(payload_bytes), ring, bidirectional, hw)

    def _ring(self, axis, cais: CAISConfig) -> int:
        return cais.interpret_n or prim._axis_size(axis)

    def _resolve(self, cais: CAISConfig, gathered_bytes: float,
                 ring: int, inter: bool = False) -> CAISConfig:
        """Fill in num_chunks from the α-β plan when the config leaves it
        open. ``gathered_bytes`` is the full (global) payload the collective
        moves around the ring; ``inter=True`` plans the leg against the
        inter-node tier of ``cais.hw`` (the 2D-mesh slow axis)."""
        if cais.num_chunks is not None or ring <= 1:
            return cais
        hw = cais.hw
        if inter:
            hw = (hw or coordination.V5E).inter_tier()
        c = _planned_chunks(int(gathered_bytes), ring, cais.bidirectional, hw)
        return dataclasses.replace(cais, num_chunks=c)

    @staticmethod
    def _nbytes(x) -> int:
        return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize

    # inter-node legs of the hierarchical compositions: ring-decomposed,
    # chunk-planned against the inter-node tier of ``cais.hw``
    def _inner_all_gather(self, x, axis, cais):
        return prim.ring_all_gather(x, axis, cais)

    def _outer_all_gather(self, x, axis, cais):
        ring = prim._axis_size(axis)
        if ring <= 1:
            return x
        cais = self._resolve(cais, self._nbytes(x) * ring, ring, inter=True)
        return prim.ring_all_gather(x, axis, cais)

    def _outer_reduce_scatter(self, x, axis, cais):
        ring = prim._axis_size(axis)
        if ring <= 1:
            return x
        if int(x.shape[1]) % ring != 0:
            return lax.psum_scatter(x, axis, scatter_dimension=1, tiled=True)
        cais = self._resolve(cais, self._nbytes(x), ring, inter=True)
        return prim.ring_reduce_scatter(x, axis, cais)

    def ag_gemm_multi(self, x, ws, axis, cais):
        if self.hierarchical(axis):
            return self.hier_ag_gemm_multi(x, ws, axis, cais)
        n = self._ring(axis, cais)
        cais = self._resolve(cais, self._nbytes(x) * n, n)
        return prim.ag_gemm_multi(x, tuple(ws), axis, cais)

    def gemm_rs(self, x, w, axis, cais):
        if self.hierarchical(axis):
            return self.hier_gemm_rs(x, w, axis, cais)
        return prim.gemm_rs(x, w, axis, cais)

    def gemm_ar(self, x, w, axis, cais):
        if self.hierarchical(axis):
            return self.hier_gemm_ar(x, w, axis, cais)
        # the decomposed RS+AG schedule sequence-shards the payload around
        # the ring; a ragged/decode sequence (S % ring != 0, e.g. S=1) can't
        # split, so THIS collective falls back to the monolithic allreduce
        # while the rest of the graph keeps the cais schedules
        if int(x.shape[1]) % self._ring(axis, cais) != 0:
            return prim.barrier_gemm_ar(x, w, axis)
        return prim.gemm_ar(x, w, axis, cais)

    def a2a_expert_ffn(self, send, ffn, axis, cais):
        if self.hierarchical(axis):
            return self.hier_a2a_expert_ffn(send, ffn, axis, cais)
        return prim.a2a_expert_ffn(send, ffn, axis, cais)

    def fused_rs_ln_ag(self, x, w1, ln_scale, w2, axis, cais,
                       norm="rmsnorm", residual=None):
        if self.hierarchical(axis):
            # base composition over this backend's guarded gemm_rs /
            # ag_gemm_multi — each tier plans its own leg inside those
            outs, z = super().fused_rs_ln_ag_multi(
                x, w1, ln_scale, (w2,), axis, cais, norm=norm,
                residual=residual)
            return outs[0], z
        # plan for the AG leg: the gathered z payload is (B, S, d) where
        # S = x.shape[1] (x is full-sequence, feature-sharded) and d = w1 cols
        n = self._ring(axis, cais)
        itemsize = np.dtype(x.dtype).itemsize
        z_bytes = int(x.shape[0]) * int(x.shape[1]) * int(w1.shape[1]) * \
            itemsize
        cais = self._resolve(cais, z_bytes, n)
        return prim.fused_rs_ln_ag(x, w1, ln_scale, w2, axis, cais,
                                   norm=norm, residual=residual)

    def fused_rs_ln_ag_multi(self, x, w1, ln_scale, ws2, axis, cais,
                             norm="rmsnorm", residual=None):
        if self.hierarchical(axis):
            return super().fused_rs_ln_ag_multi(x, w1, ln_scale, tuple(ws2),
                                                axis, cais, norm=norm,
                                                residual=residual)
        # same planning as fused_rs_ln_ag — the gathered z payload governs
        # both legs; with num_chunks resolved, the base-class composition
        # over this backend's gemm_rs / ag_gemm_multi is the schedule
        n = self._ring(axis, cais)
        itemsize = np.dtype(x.dtype).itemsize
        z_bytes = int(x.shape[0]) * int(x.shape[1]) * int(w1.shape[1]) * \
            itemsize
        cais = self._resolve(cais, z_bytes, n)
        return super().fused_rs_ln_ag_multi(x, w1, ln_scale, tuple(ws2),
                                            axis, cais, norm=norm,
                                            residual=residual)

    def fused_rs_ln(self, x, w1, ln_scale, axis, cais,
                    norm="rmsnorm", residual=None):
        if self.hierarchical(axis):
            return super().fused_rs_ln(x, w1, ln_scale, axis, cais,
                                       norm=norm, residual=residual)
        # plan for the RS leg like fused_rs_ln_ag: the z payload the ring
        # moves is (B, S, d) with d = w1 cols
        n = self._ring(axis, cais)
        itemsize = np.dtype(x.dtype).itemsize
        z_bytes = int(x.shape[0]) * int(x.shape[1]) * int(w1.shape[1]) * \
            itemsize
        cais = self._resolve(cais, z_bytes, n)
        return super().fused_rs_ln(x, w1, ln_scale, axis, cais, norm=norm,
                                   residual=residual)

    def grad_ag_gemm(self, d, wT, axis, cais):
        if self.hierarchical(axis):
            return self.hier_grad_ag_gemm(d, wT, axis, cais)
        # decomposed bidirectional ring gather of the cotangent, then the
        # GEMM against the transposed shard — the grad-side mirror of the
        # forward pull alignment
        n = self._ring(axis, cais)
        cais = self._resolve(cais, self._nbytes(d) * n, n)
        g = prim.ring_all_gather(d, axis, cais)
        return g @ wT, g

    def grad_a2a_expert_ffn(self, send, gy, bwd_row, axis, cais):
        if self.hierarchical(axis):
            return self.hier_grad_a2a_expert_ffn(send, gy, bwd_row, axis,
                                                 cais)
        # interleaved per-offset ± schedule mirroring the forward a2a; the
        # chunking is structural (one (row, cotangent) pair per offset —
        # splitting a row along C would break the E_loc·cap expert
        # segmentation), so no _resolve here; the planner prices the 2×
        # dispatch payload instead (plan/lower.py)
        return prim.grad_a2a_expert_ffn(send, gy, bwd_row, axis, cais)

    def overlap_asymmetric(self, rs_args, ag_args, axis, cais):
        if self.hierarchical(axis):
            return self.hier_overlap_asymmetric(rs_args, ag_args, axis, cais)
        # no _resolve: the lockstep schedule moves one S_loc slice per hop
        # on each stream — its chunking is structural, not planner-chosen
        return prim.overlap_asymmetric(rs_args, ag_args, axis, cais)


# ---------------------------------------------------------------------------
# auto — XLA-scheduled reference
# ---------------------------------------------------------------------------


class AutoBackend(BarrierBackend):
    """Defer scheduling to the compiler. ``explicit = False`` tells the model
    path to skip shard_map and express TP purely via sharding constraints
    (the strong compiler baseline); the inherited monolithic methods remain
    available so graphs can still be executed under this backend."""

    name = "auto"
    explicit = False


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, CollectiveBackend] = {}


def register_backend(backend: CollectiveBackend,
                     name: Optional[str] = None) -> CollectiveBackend:
    """Register (or replace) a backend under ``name or backend.name``."""
    key = name or backend.name
    if not key or key == "abstract":
        raise ValueError("backend must carry a concrete name")
    _REGISTRY[key] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: Union[str, CollectiveBackend]) -> CollectiveBackend:
    """Resolve a backend by name (instances pass through unchanged)."""
    if isinstance(name, CollectiveBackend):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown collective backend {name!r}; available: "
            f"{available_backends()}") from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


register_backend(BarrierBackend())
register_backend(CAISBackend())
register_backend(AutoBackend())
