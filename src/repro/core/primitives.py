"""CAIS-on-TPU core primitives: decomposed collective-fused GEMM schedules.

The paper's insight (DESIGN.md §2): communication must follow the compute
kernel's memory semantics so data is consumed/produced chunk-by-chunk with no
global barrier between the collective and the GEMM.

On a TPU torus that lowers to *ring schedules of ``collective_permute``
interleaved with partial GEMMs* inside ``shard_map``:

  * :func:`ag_gemm`   — pull-aligned AllGather→GEMM (the paper's ld.cais):
    each ring step's arriving activation chunk is immediately consumed by a
    partial GEMM; XLA's latency-hiding scheduler overlaps permute *k+1* with
    dot *k* (the HLO shows ``collective-permute-start/done`` straddling dots).
  * :func:`gemm_rs`   — push-aligned GEMM→ReduceScatter (the paper's
    red.cais): a rotating accumulator is summed "in flight" hop by hop — the
    ring is the merge unit.
  * :func:`gemm_ar`   — AR = RS + AG, as the paper decomposes it.
  * :func:`fused_rs_ln_ag` — the graph-level optimizer's target chain
    GEMM-RS + LN + AG-GEMM (paper sub-layers L1–L4) in one pipeline.
  * ``barrier_*``     — the NVLS-style baselines: one monolithic collective
    HLO op around the GEMM (communication as an opaque phase).

``num_chunks`` micro-chunks the local shard so each permute carries
``payload/num_chunks`` bytes — the per-step staging buffer is the merge-table
analogue (paper Fig. 13/14). ``bidirectional=True`` splits micro-chunks
across the two ring directions (full-duplex ICI), the asymmetric-overlap
analogue (paper Fig. 9e/10).

All functions here run INSIDE ``shard_map`` (they use ``lax.axis_index`` /
``lax.ppermute``). ``repro.core.tp`` wraps them for pjit callers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


DEFAULT_NUM_CHUNKS = 4


@dataclass(frozen=True)
class CAISConfig:
    """Chunking/scheduling knobs (see repro.core.coordination).

    ``num_chunks=None`` leaves the chunking open: the ``cais``
    :mod:`repro.core.backends` backend then plans it per collective from
    payload bytes and ring size via ``coordination.plan``; primitives called
    directly fall back to ``DEFAULT_NUM_CHUNKS``. An explicit integer is a
    static override honored everywhere.

    ``hw`` is the :class:`repro.hw.HWSpec` the planner plans against
    (``None`` → the default V5E). On hierarchical 2D meshes the backend
    plans inter-node legs with ``hw.inter_tier()`` — the satellite fix for
    planning every axis against the flat-ring bandwidth."""

    num_chunks: Optional[int] = None   # micro-chunks per local shard
    bidirectional: bool = True         # use both ring directions
    interpret_n: Optional[int] = None  # override ring size (tests)
    hw: Optional[object] = None        # repro.hw.HWSpec for chunk planning


def _ring_perms(n: int, direction: int) -> Sequence[Tuple[int, int]]:
    if direction > 0:
        return [(i, (i + 1) % n) for i in range(n)]
    return [(i, (i - 1) % n) for i in range(n)]


def _axis_size(axis: str) -> int:
    from repro.sharding import shard_map_axis_size
    return shard_map_axis_size(axis)


# ---------------------------------------------------------------------------
# Barrier (NVLS-style) baselines — one opaque collective around the GEMM
# ---------------------------------------------------------------------------


def barrier_ag_gemm(x: jnp.ndarray, w: jnp.ndarray, axis: str) -> jnp.ndarray:
    """x: (B, S_loc, d) seq-sharded; w: (d, F_loc). Returns (B, S, F_loc).

    ``all_gather`` completes in full before the GEMM starts — the
    communication-centric phase structure of TP-NVLS/SP-NVLS."""
    xg = lax.all_gather(x, axis, axis=1, tiled=True)  # (B, S, d)
    return xg @ w


def barrier_gemm_rs(x: jnp.ndarray, w: jnp.ndarray, axis: str) -> jnp.ndarray:
    """x: (B, S, d_loc) feature-sharded; w: (d_loc, F). Returns (B, S_loc, F)
    reduced over the axis and scattered on S."""
    y = x @ w                                    # full-size partial product
    return lax.psum_scatter(y, axis, scatter_dimension=1, tiled=True)


def barrier_gemm_ar(x: jnp.ndarray, w: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Basic-TP row-parallel GEMM + AllReduce."""
    return lax.psum(x @ w, axis)


# ---------------------------------------------------------------------------
# CAIS AG-GEMM: pull-aligned decomposed all-gather matmul
# ---------------------------------------------------------------------------


def ag_gemm_multi(x: jnp.ndarray, ws: Sequence[jnp.ndarray], axis: str,
                  cais: CAISConfig = CAISConfig()) -> Tuple[jnp.ndarray, ...]:
    """Decomposed AllGather→GEMM against several weights sharing one gather
    (fused QKV / gate+up projections: the activation circulates once, every
    weight consumes each chunk).

    x: (B, S_loc, d) sequence-sharded input; ws[k]: (d, F_k_loc)
    column-sharded weights. Returns one (B, S_loc*n, F_k_loc) per weight —
    identical to ``barrier_ag_gemm`` per weight.
    """
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return tuple(x @ w for w in ws)
    B, S_loc, d = x.shape
    i = lax.axis_index(axis)

    c = _pick_chunks(S_loc, cais.num_chunks)
    half = c // 2 if (cais.bidirectional and c >= 2) else c
    # (c, B, S_loc/c, d) micro-chunks
    xs = x.reshape(B, c, S_loc // c, d).transpose(1, 0, 2, 3)

    fwd = _ring_perms(n, +1)
    bwd = _ring_perms(n, -1)

    def step(carry, _):
        chunks = carry
        parts = []
        new_chunks = []
        for j in range(c):
            # consume the chunk we currently hold...
            parts.append(tuple(chunks[j] @ w for w in ws))
            # ...while its forward permute is in flight (data-independent)
            perm = fwd if j < half else bwd
            new_chunks.append(lax.ppermute(chunks[j], axis, perm))
        ys = tuple(jnp.stack([p[k] for p in parts]) for k in range(len(ws)))
        return tuple(new_chunks), ys  # per weight: (c, B, s, F_k)

    chunks0 = tuple(xs[j] for j in range(c))
    _, parts = lax.scan(step, chunks0, None, length=n)

    # Reassemble: at step t, micro-chunk j (direction ±1) originated at
    # device (i ∓ t) mod n — a pure ROTATION of the step axis, so ordering
    # is a roll (two slices + concat), not a scatter (§Perf iteration 6:
    # the scatter was the CAIS memory-term overhead).
    #   fwd: ordered[j] = parts[(i−j)%n] = roll(flip(parts), i+1)
    #   bwd: ordered[j] = parts[(j−i)%n] = roll(parts, i)
    outs = []
    for k in range(len(ws)):
        pk = parts[k]  # (n, c, B, s, F_k)
        out_rows = []
        for j in range(c):
            if j < half:
                ordered = jnp.roll(jnp.flip(pk[:, j], axis=0), i + 1, axis=0)
            else:
                ordered = jnp.roll(pk[:, j], i, axis=0)
            out_rows.append(ordered)  # (n, B, s, F)
        out = jnp.stack(out_rows, axis=1)
        # (n, c, B, s, F) -> (B, n*c*s, F) with row order (shard, chunk, s)
        outs.append(out.transpose(2, 0, 1, 3, 4).reshape(
            B, n * S_loc, ws[k].shape[1]))
    return tuple(outs)


def ag_gemm(x: jnp.ndarray, w: jnp.ndarray, axis: str,
            cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """Decomposed AllGather→GEMM (single weight). See :func:`ag_gemm_multi`."""
    return ag_gemm_multi(x, (w,), axis, cais)[0]


def _pick_chunks(s_loc: int, requested: Optional[int]) -> int:
    if requested is None:
        requested = DEFAULT_NUM_CHUNKS
    c = max(1, min(requested, s_loc))
    while s_loc % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# CAIS GEMM-RS: push-aligned decomposed matmul reduce-scatter
# ---------------------------------------------------------------------------


def gemm_rs(x: jnp.ndarray, w: jnp.ndarray, axis: str,
            cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """Decomposed GEMM→ReduceScatter.

    x: (B, S, d_loc) feature-sharded input; w: (d_loc, F) row-sharded weight.
    Returns (B, S_loc, F): the reduced output scattered on the sequence —
    identical to ``barrier_gemm_rs``, but each output shard's partial GEMM is
    computed just-in-time as the rotating accumulator arrives (reduction "in
    flight": the ring hop is the merge unit).
    """
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return x @ w
    B, S, d_loc = x.shape
    F = w.shape[1]
    S_loc = S // n
    i = lax.axis_index(axis)

    def partial(j):
        """Local partial product for destination shard j: (B, S_loc, F)."""
        xc = lax.dynamic_slice_in_dim(x, j * S_loc, S_loc, axis=1)
        return xc @ w

    if cais.bidirectional and n % 2 == 0 and S_loc % 2 == 0:
        # split S_loc rows in half; each half reduced around opposite rings
        # (odd S_loc can't split evenly — the unidirectional ring below
        # handles it; S_loc == 1 shows up on serve-period graphs at S == n)
        h = S_loc // 2

        def partial_half(j, lo):
            xc = lax.dynamic_slice_in_dim(x, j * S_loc + lo, h, axis=1)
            return xc @ w

        fwd = _ring_perms(n, +1)
        bwd = _ring_perms(n, -1)

        def step(carry, t):
            accf, accb = carry
            accf = lax.ppermute(accf, axis, fwd)
            accb = lax.ppermute(accb, axis, bwd)
            jf = (i - 1 - t) % n     # fwd acc now holds shard i-1-t
            jb = (i + 1 + t) % n     # bwd acc now holds shard i+1+t
            return (accf + partial_half(jf, 0),
                    accb + partial_half(jb, h)), None

        acc0 = (partial_half((i - 1) % n, 0), partial_half((i + 1) % n, h))
        (accf, accb), _ = lax.scan(step, acc0, jnp.arange(1, n))
        return jnp.concatenate([accf, accb], axis=1)

    fwd = _ring_perms(n, +1)

    def step(acc, t):
        acc = lax.ppermute(acc, axis, fwd)
        j = (i - 1 - t) % n
        return acc + partial(j), None

    acc0 = partial((i - 1) % n)
    acc, _ = lax.scan(step, acc0, jnp.arange(1, n))
    return acc


def gemm_ar(x: jnp.ndarray, w: jnp.ndarray, axis: str,
            cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """Basic-TP GEMM→AllReduce as RS + AG (both decomposed).

    x: (B, S, d_loc); w: (d_loc, F). Returns (B, S, F) fully reduced."""
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return x @ w
    y_loc = gemm_rs(x, w, axis, cais)       # (B, S_loc, F)
    return ring_all_gather(y_loc, axis, cais)


def ring_all_gather(x: jnp.ndarray, axis: str,
                    cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """Decomposed (bidirectional) ring all-gather along dim 1."""
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return x
    i = lax.axis_index(axis)
    B, S_loc = x.shape[0], x.shape[1]

    fwd = _ring_perms(n, +1)
    bwd = _ring_perms(n, -1)

    if cais.bidirectional and S_loc >= 2:
        h = S_loc // 2
        xf, xb = x[:, :h], x[:, h:]

        def step(carry, _):
            cf, cb = carry
            nf = lax.ppermute(cf, axis, fwd)
            nb = lax.ppermute(cb, axis, bwd)
            return (nf, nb), (cf, cb)

        _, (pf, pb) = lax.scan(step, (xf, xb), None, length=n)
        of = jnp.roll(jnp.flip(pf, axis=0), i + 1, axis=0)
        ob = jnp.roll(pb, i, axis=0)
        out = jnp.concatenate([of, ob], axis=2)
        return out.transpose(1, 0, *range(2, out.ndim)).reshape(
            B, n * S_loc, *x.shape[2:])

    def step(chunk, _):
        return lax.ppermute(chunk, axis, fwd), chunk

    _, parts = lax.scan(step, x, None, length=n)
    ordered = jnp.roll(jnp.flip(parts, axis=0), i + 1, axis=0)
    return ordered.transpose(1, 0, *range(2, ordered.ndim)).reshape(
        B, n * S_loc, *x.shape[2:])


def ring_reduce_scatter(x: jnp.ndarray, axis: str,
                        cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """Decomposed (bidirectional) ring reduce-scatter along dim 1 — the
    standalone counterpart of :func:`gemm_rs`'s rotating accumulator, used
    as the outer-tier (inter-node) leg of hierarchical compositions where
    the GEMM already happened on the inner ring."""
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return x
    S = x.shape[1]
    S_loc = S // n
    i = lax.axis_index(axis)

    def part(j, lo, ln):
        return lax.dynamic_slice_in_dim(x, j * S_loc + lo, ln, axis=1)

    if cais.bidirectional and n % 2 == 0 and S_loc % 2 == 0:
        h = S_loc // 2
        fwd = _ring_perms(n, +1)
        bwd = _ring_perms(n, -1)

        def step(carry, t):
            accf, accb = carry
            accf = lax.ppermute(accf, axis, fwd)
            accb = lax.ppermute(accb, axis, bwd)
            jf = (i - 1 - t) % n
            jb = (i + 1 + t) % n
            return (accf + part(jf, 0, h), accb + part(jb, h, h)), None

        acc0 = (part((i - 1) % n, 0, h), part((i + 1) % n, h, h))
        (accf, accb), _ = lax.scan(step, acc0, jnp.arange(1, n))
        return jnp.concatenate([accf, accb], axis=1)

    fwd = _ring_perms(n, +1)

    def step(acc, t):
        acc = lax.ppermute(acc, axis, fwd)
        return acc + part((i - 1 - t) % n, 0, S_loc), None

    acc, _ = lax.scan(step, part((i - 1) % n, 0, S_loc), jnp.arange(1, n))
    return acc


# ---------------------------------------------------------------------------
# CAIS expert all-to-all: decomposed dispatch/compute/combine pipeline (EP)
# ---------------------------------------------------------------------------


def barrier_a2a_expert_ffn(send: jnp.ndarray, ffn: Callable, axis: str
                           ) -> jnp.ndarray:
    """EP baseline: monolithic dispatch all-to-all → expert FFN → combine
    all-to-all (three isolated phases — the NVLS-style structure).

    send: (n, C, d) — send[j] holds this device's token chunk routed to the
    expert(s) owned by device j. ffn: (C, d) -> (C, d) local expert compute.
    Returns (n, C, d): out[j] = FFN_j(send[j]) (owner-j's experts applied)."""
    n = send.shape[0]
    recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                          tiled=False)
    h = jax.vmap(ffn)(recv)
    return lax.all_to_all(h, axis, split_axis=0, concat_axis=0, tiled=False)


def a2a_expert_ffn(send: jnp.ndarray, ffn: Callable, axis: str,
                   cais: CAISConfig = CAISConfig()) -> jnp.ndarray:
    """CAIS-decomposed expert all-to-all (beyond the paper: §Perf found the
    published technique leaves MoE's dominant collective untouched).

    Per offset o = 1..n−1 the dispatch permute (+o direction) of chunk o,
    the expert FFN on the chunk that just arrived, and the combine permute
    (−o direction) of the previous result are all in flight together — the
    dispatch and combine streams occupy OPPOSITE link directions every step
    (the asymmetric kernel overlap of paper Fig. 9e, applied to EP).

    Same contract as :func:`barrier_a2a_expert_ffn`. Note: offset-o permutes
    are single HLO ops that a torus lowers to ≤o hops; the dry-run's
    byte accounting counts payload once per permute (same as a2a's slices).
    """
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        return jax.vmap(ffn)(send)
    i = lax.axis_index(axis)
    C, d = send.shape[1], send.shape[2]

    def perm_for(offset: int):
        return [(s, (s + offset) % n) for s in range(n)]

    # local chunk computes immediately (no wire)
    out0 = ffn(_take_row(send, i))
    results = jnp.zeros_like(send)
    results = _dus_row(results, out0, i)

    for o in range(1, n):
        # alternate ± offsets so consecutive dispatches balance directions
        off = o if not cais.bidirectional else ((o + 1) // 2 if o % 2
                                                else -(o // 2))
        # dispatch chunk destined o "slots" away (direction ±)
        dst = (i + off) % n
        chunk = _take_row(send, dst)
        arrived = lax.ppermute(chunk, axis, perm_for(off))  # from (i-off)
        h = ffn(arrived)
        # combine travels the opposite direction back to the origin
        returned = lax.ppermute(h, axis, perm_for(-off))
        # `returned` is the FFN output of MY tokens computed by (i+off)
        results = _dus_row(results, returned, dst)
    return results


def _take_row(x: jnp.ndarray, idx) -> jnp.ndarray:
    return lax.dynamic_index_in_dim(x, idx, axis=0, keepdims=False)


def _dus_row(x: jnp.ndarray, row: jnp.ndarray, idx) -> jnp.ndarray:
    return lax.dynamic_update_index_in_dim(x, row, idx, axis=0)


def grad_a2a_expert_ffn(send: jnp.ndarray, gy: jnp.ndarray,
                        bwd_row: Callable, axis: str,
                        cais: CAISConfig = CAISConfig()):
    """CAIS-decomposed adjoint of :func:`a2a_expert_ffn`.

    Mirrors the forward's interleaved per-offset schedule: each step the
    grad-dispatch permute (+o direction) carries the (send row, output
    cotangent row) pair to the owning expert, the per-row expert VJP runs
    on the pair that just arrived, and the chunk-cotangent combine permute
    (−o direction) returns the previous result to its sender — dispatch
    and combine again ride OPPOSITE link directions every step. The
    dispatch payload is 2× the forward's (row + cotangent travel
    together); the planner prices both directions (plan/lower.py).

    ``bwd_row(chunk, gy_row) -> (d_chunk, dw_tuple)`` is the per-row
    expert VJP built by the executor. Expert weight grads accumulate
    LOCALLY at the owner — they never ride a collective. Returns
    ``(d_send, dw_tuple)`` with ``d_send`` shaped like ``send``.
    """
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        d_rows, dw_rows = jax.vmap(bwd_row)(send, gy)
        return d_rows, tuple(jnp.sum(a, axis=0) for a in dw_rows)
    i = lax.axis_index(axis)

    def perm_for(offset: int):
        return [(s, (s + offset) % n) for s in range(n)]

    # local row: my tokens routed to my own experts (no wire)
    d0, dws = bwd_row(_take_row(send, i), _take_row(gy, i))
    d_send = jnp.zeros_like(send)
    d_send = _dus_row(d_send, d0, i)

    for o in range(1, n):
        # same ± alternation as the forward so directions stay balanced
        off = o if not cais.bidirectional else ((o + 1) // 2 if o % 2
                                                else -(o // 2))
        dst = (i + off) % n
        # grad-dispatch: the row AND its output cotangent travel together
        arr_c = lax.ppermute(_take_row(send, dst), axis, perm_for(off))
        arr_g = lax.ppermute(_take_row(gy, dst), axis, perm_for(off))
        d_chunk, dw_o = bwd_row(arr_c, arr_g)  # my experts' VJP
        # chunk cotangent travels the opposite direction back to sender
        returned = lax.ppermute(d_chunk, axis, perm_for(-off))
        d_send = _dus_row(d_send, returned, dst)
        dws = tuple(a + b for a, b in zip(dws, dw_o))
    return d_send, dws


# ---------------------------------------------------------------------------
# Fused sub-layer: GEMM-RS + LN + AG-GEMM (the paper's L1–L4 chain)
# ---------------------------------------------------------------------------


def fused_rs_ln_ag(x: jnp.ndarray, w1: jnp.ndarray, ln_scale: jnp.ndarray,
                   w2: jnp.ndarray, axis: str,
                   cais: CAISConfig = CAISConfig(),
                   norm: str = "rmsnorm",
                   residual: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The graph-level dataflow optimizer's fused pipeline (DESIGN.md §4).

    x: (B, S, d1_loc) feature-sharded → GEMM-RS → (B, S_loc, d) → (+residual)
    → LN (sequence-parallel, no collective) → AG-GEMM → (B, S, d2_loc).

    The RS ring runs +1 and the AG ring −1 (and each is internally
    bidirectional), so both directions of every ICI link carry payload —
    the asymmetric kernel overlap of paper Fig. 9(e)/Fig. 10.
    """
    from repro.models.layers import apply_norm  # local import; no cycle

    z = gemm_rs(x, w1, axis, cais)                      # push-aligned
    if residual is not None:
        z = z + residual
    zn = apply_norm(norm, {"scale": ln_scale}, z)       # seq-sharded LN
    out = ag_gemm(zn, w2, axis, cais)                   # pull-aligned
    return out, z


# ---------------------------------------------------------------------------
# Asymmetric dual-stream overlap: two independent chains, opposite traffic
# ---------------------------------------------------------------------------


def overlap_asymmetric(rs_args, ag_args, axis: str,
                       cais: CAISConfig = CAISConfig()):
    """Run an independent GEMM-RS and AG-GEMM *in lockstep*, one scan: each
    step issues one RS hop (+1 ring) and one AG hop (−1 ring) plus both
    partial GEMMs. This is the direct analogue of the paper's asymmetric
    kernel overlapping (two kernels with complementary traffic sharing the
    link bidirectionally).

    rs_args: (x_rs (B,S,d_loc), w_rs (d_loc,F)); ag_args: (x_ag (B,S_loc,d),
    w_ag (d,F_loc) — or a tuple of such weights sharing the one AG
    circulation, e.g. a paired ``ag_gemm_multi``). Returns
    (rs_out (B,S_loc,F), ag_out (B,S,F_loc)) — ``ag_out`` is a tuple of
    per-weight outputs when ``w_ag`` is a tuple.
    """
    x_rs, w_rs = rs_args
    x_ag, w_ag = ag_args
    multi = isinstance(w_ag, (tuple, list))
    ws_ag = tuple(w_ag) if multi else (w_ag,)
    n = cais.interpret_n or _axis_size(axis)
    if n == 1:
        outs = tuple(x_ag @ w for w in ws_ag)
        return x_rs @ w_rs, (outs if multi else outs[0])
    i = lax.axis_index(axis)
    B, S, _ = x_rs.shape
    S_loc = S // n

    fwd = _ring_perms(n, +1)
    bwd = _ring_perms(n, -1)

    def rs_partial(j):
        xc = lax.dynamic_slice_in_dim(x_rs, j * S_loc, S_loc, axis=1)
        return xc @ w_rs

    def step(carry, t):
        acc, chunk = carry
        # RS stream on the +1 direction
        acc = lax.ppermute(acc, axis, fwd)
        acc = acc + rs_partial((i - 1 - t) % n)
        # AG stream on the −1 direction (data-independent of the RS stream)
        part = tuple(chunk @ w for w in ws_ag)
        chunk = lax.ppermute(chunk, axis, bwd)
        return (acc, chunk), part

    acc0 = rs_partial((i - 1) % n)
    part0 = tuple(x_ag @ w for w in ws_ag)
    chunk0 = lax.ppermute(x_ag, axis, bwd)
    (acc, _), parts = lax.scan(step, (acc0, chunk0), jnp.arange(1, n))

    ag_outs = []
    for k in range(len(ws_ag)):
        pk = jnp.concatenate([part0[k][None], parts[k]], axis=0)  # (n,B,s,F)
        ordered = jnp.roll(pk, i, axis=0)   # ordered[j] = parts[(j−i)%n]
        ag_outs.append(ordered.transpose(1, 0, 2, 3).reshape(B, n * S_loc, -1))
    return acc, (tuple(ag_outs) if multi else ag_outs[0])
