"""Graph-level dataflow optimizer (paper §III-C).

A small dataflow IR over TP transformer blocks — and, since the period-level
refactor, over whole ``layer_pattern`` *periods* (≥1 blocks chained into one
graph, see :func:`repro.core.tp.sp_period`) — plus fusion passes.

Op vocabulary (and which optimizer pass consumes each op)
---------------------------------------------------------

Primitive ops (emitted by the graph builders in :mod:`repro.core.tp`):

``input``
    Declares a graph value. Consumed by no pass.
``gemm_col`` / ``gemm_row``
    Column-/row-sharded projection. Pass 1 (``fuse_compute_aware``) aligns
    the adjacent collective with the GEMM's memory semantics; pass 1b
    (``fuse_shared_gather``) merges several ``gemm_col`` consumers of one
    gather into ``ag_gemm_multi``.
``allgather`` / ``reduce_scatter`` / ``allreduce``
    The raw collectives. Consumed by pass 1/1b into the fused forms below.
``layernorm``
    Sequence-parallel norm (no collective). Consumed by pass 2
    (``fuse_sublayer_chain``) when it sits on an rs→ln→ag seam.
``add`` / ``residual``
    Elementwise sum; ``residual`` marks the block's residual connection
    (main branch first, skip second). Pass 2 folds either into the fused
    chain and re-exposes the post-add value.
``custom``
    Arbitrary *local* math (activation, attention core, dense-residual MLP)
    — it never touches the mesh, so every pass may move collectives
    around it. ``fn(*inputs, *weights)``.
``route`` / ``unroute``
    Top-k expert routing: ``route`` turns a normed activation into the
    per-owner send buffer (+ combine weights + aux loss), ``unroute``
    scatters expert outputs back to token order. Local math like
    ``custom`` (multi-output capable); no pass rewrites them today — they
    exist as named ops so future passes can schedule the expert all-to-all
    against the dense residual.
``a2a_ffn``
    Expert all-to-all + expert FFN, dispatched through
    ``CollectiveBackend.a2a_expert_ffn`` (the ``cais`` backend overlaps
    ±direction dispatch/combine permutes with the expert GEMMs).

Fused ops (produced by ``optimize``, executed via the backend):

``ag_gemm`` / ``ag_gemm_multi``
    Pull-aligned AllGather→GEMM (one or several weights sharing one ring
    circulation). Produced by pass 1 / 1b; pass 2 and 3 consume them.
``gemm_rs`` / ``gemm_ar``
    Push-aligned GEMM→ReduceScatter / →AllReduce. Produced by pass 1;
    pass 2 and 3 consume ``gemm_rs``.
``fused_rs_ln_ag`` / ``fused_rs_ln_ag_multi``
    Deep fusion of the ``gemm_rs → [add|residual] → layernorm →
    ag_gemm[_multi]`` sub-layer seam (Fig. 9) — the whole-block graph's
    attention-out → FFN-in chain, and (in a period graph) the block→block
    seam: block k's FFN-out RS → residual → block k+1's LN1 → QKV shared
    gather. Produced by pass 2 (terminal).
``fused_rs_ln``
    The gather-less prefix of the same seam: ``gemm_rs → [add|residual] →
    layernorm`` whose normed value feeds a ``route`` node (the MoE
    attention-out → router seam) — the trailing collective is the expert
    all-to-all, not an allgather, so only the RS + add + norm fuse. Outputs
    ``(normed, z)``; produced by pass 2 (terminal), executed via
    ``CollectiveBackend.fused_rs_ln``.
``overlap_asym``
    Co-scheduled independent ``gemm_rs`` + ``ag_gemm[_multi]`` pair with
    complementary ring directions (asymmetric kernel overlapping,
    Fig. 9e/10). Produced by pass 3 (``pair_asymmetric``, terminal).
    Pairing is deterministic and nearest-independent-pair-first: candidate
    pairs are ranked by topological distance (ties: earliest position, then
    node names), so a merged microbatch/period graph picks the adjacent
    seam — one chain's FFN-out RS against the *nearest* independent
    attention gather — rather than an arbitrary first match. Candidates
    must come from *different* chains (disjoint ``input``-ancestor sets):
    two collectives fed by the same microbatch's data never pair, even when
    a fork makes them dependency-free, so a chain is never lockstep-
    serialized against itself.

``bwd_ag_gemm``
    Backward-only: the adjoint of ``gemm_rs`` — AllGather the seq-sharded
    output cotangent, GEMM with the transposed weight, and re-expose the
    gathered cotangent for the weight-gradient GEMM. Emitted by
    :func:`build_training_graph` (never by the forward builders), executed
    via ``CollectiveBackend.grad_ag_gemm``.
``bwd_a2a_ffn``
    Backward-only: the adjoint of ``a2a_ffn`` — re-dispatch the forward
    send buffer together with the output cotangent to each expert owner
    (forward-direction all-to-all), run the per-row VJP of the expert FFN
    there, return ``d(recv)`` to the senders (reverse all-to-all) and keep
    the local expert-weight grads on the owner. Emitted by
    :func:`build_training_graph`, executed via
    ``CollectiveBackend.grad_a2a_expert_ffn`` (the ``cais`` backend
    interleaves the ±offset dispatch/return permutes with the VJP GEMMs;
    the hierarchical composition keeps grouped-EP grads off the fast
    ``tp_in`` axis).

A worked trace of a 2-block period through every pass lives in
``docs/architecture.md``; ``docs/backends.md`` documents the backend methods
each fused op dispatches to; ``docs/training.md`` documents the
backward-graph builder (:func:`build_training_graph`) and the per-op
adjoint table (``ADJOINTS``).

The executor runs a graph either as pure math (no mesh; reference) or inside
``shard_map`` (explicit TP), dispatching every fused collective op through a
:class:`repro.core.backends.CollectiveBackend` — the model blocks
(``repro.core.tp.sp_block`` and the per-sub-layer ``sp_ffn`` /
``sp_attention``) are built, optimized, and run through this IR. Tensor
layout conventions per value: ``seq`` (B, S_loc, d) sequence-sharded ·
``feat`` (B, S, d_loc) feature-sharded · ``full`` (B, S, d) replicated.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.primitives import CAISConfig

# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------

#        op            inputs                weights         out layout
# input                ()                    —               declared
# gemm_col             (x: full)             w (d, F/n)      feat
# gemm_row             (x: feat)             w (d/n, F)      partial-full
# allgather            (x: seq)              —               full
# reduce_scatter       (x: partial-full)     —               seq
# allreduce            (x: partial-full)     —               full
# layernorm            (x: any)              scale (d,)      same
# add / residual       (a, b) same layout    —               same
# custom               (any...)              (w...)          fn-defined
# route                (xn: seq)             (router,)       (send, combine,
#                                                             aux)
# unroute              (eout, combine, xn)   —               seq
# a2a_ffn              (send,)               (expert ws...)  send-shaped
# --- fused (produced by optimize) ---
# ag_gemm              (x: seq)              w               feat
# ag_gemm_multi        (x: seq)              (w...)          feat per weight
# gemm_rs              (x: feat)             w               seq
# gemm_ar              (x: feat)             w               full
# fused_rs_ln_ag       (x: feat[, res:seq])  (w1, scale, w2) feat (+ seq z)
# fused_rs_ln_ag_multi (x: feat[, res:seq])  (w1, scale, w...) feat per w
#                                                             (+ seq z)
# fused_rs_ln          (x: feat[, res:seq])  (w1, scale)     (seq zn, seq z)
# overlap_asym         (x_rs: feat, x_ag: seq) (w_rs, w_ag...) (seq, feat...)
# bwd_ag_gemm          (dy: seq)             wT (d, F/n)     (feat dx, full dy)
# bwd_a2a_ffn          (send, dy) send-shaped (expert ws...)  (d_send, dw...)

VALID_OPS = {
    "input", "gemm_col", "gemm_row", "allgather", "reduce_scatter",
    "allreduce", "layernorm", "add", "residual", "custom",
    "route", "unroute", "a2a_ffn",
    "ag_gemm", "ag_gemm_multi", "gemm_rs", "gemm_ar", "fused_rs_ln_ag",
    "fused_rs_ln_ag_multi", "fused_rs_ln", "overlap_asym",
    "bwd_ag_gemm", "bwd_a2a_ffn",
}

# Declared adjoint vocabulary (docs/training.md): the backward-graph builder
# (:func:`build_training_graph`) knows how to emit adjoint nodes for exactly
# these forward ops — every op the model builders can leave in a period
# graph after passes 1/1b/2, MoE routing and the ragged/decode layouts
# included. Each entry maps a forward op to the IR ops its adjoint emits, so
# the backward is itself a dataflow graph the optimizer (and the perfsim
# planner) schedules: ``ag_gemm[_multi]`` ↔ a grad reduce-scatter
# (``gemm_rs`` over the transposed weight), ``gemm_rs`` ↔ a grad all-gather
# (``bwd_ag_gemm``), ``fused_rs_ln_ag[_multi]`` / ``fused_rs_ln`` ↔ the
# fused composition of both around the norm's VJP, ``a2a_ffn`` ↔ the
# reverse expert all-to-all (``bwd_a2a_ffn``), ``route``/``unroute`` ↔
# local ``jax.vjp`` of the routing closures (the aux-loss side-output's
# cotangent seeds the router-logit grads), ``gemm_ar`` ↔ purely local math
# (its output is replicated, so dx/dw need no collective), ``gemm_col`` ↔ a
# grad allreduce (a backward ``gemm_ar`` over the transposed weight — the
# sequence-parallel-off layout's backbone). Graphs containing any other op
# (raw collectives, pass-3 ``overlap_asym``) report
# ``supports_backward() == False`` and keep JAX autodiff of the executed
# forward graph.
ADJOINTS = {
    "input": (),
    "add": (), "residual": (),              # gradient fan-out, no new nodes
    "layernorm": ("custom",),               # norm VJP (local math)
    "custom": ("custom",),                  # jax.vjp of the node's fn
    "route": ("custom",),                   # jax.vjp of the routing closure
    "unroute": ("custom",),                 # the route adjoint's dual
    "a2a_ffn": ("bwd_a2a_ffn",),            # reverse expert all-to-all
    "ag_gemm": ("custom", "gemm_rs", "allgather"),
    "ag_gemm_multi": ("custom", "gemm_rs", "allgather"),
    "gemm_rs": ("bwd_ag_gemm", "custom"),
    "gemm_ar": ("custom",),                 # replicated out: local dx/dw
    "gemm_col": ("gemm_ar", "custom"),      # grad allreduce through w^T
    "fused_rs_ln_ag": ("custom", "gemm_rs", "bwd_ag_gemm", "allgather"),
    "fused_rs_ln_ag_multi": ("custom", "gemm_rs", "bwd_ag_gemm",
                             "allgather"),
    "fused_rs_ln": ("custom", "bwd_ag_gemm"),
}

# local-math ops whose semantics live in the node's `fn`
_FN_OPS = ("custom", "route", "unroute")


class GraphError(ValueError):
    """A malformed dataflow graph: unknown op, cycle, missing producer…
    Always names the offending node/value."""


@dataclass(frozen=True)
class Node:
    name: str
    op: str
    inputs: Tuple[str, ...] = ()
    weights: Tuple[str, ...] = ()   # keys into the weights dict
    outputs: Tuple[str, ...] = ()   # multi-output fused ops; default (name,)
    fn: Optional[Callable] = None   # local math for fn-carrying ops

    def __post_init__(self):
        if self.op not in VALID_OPS:
            raise GraphError(
                f"node {self.name!r} has unknown dataflow op {self.op!r}; "
                f"valid ops: {sorted(VALID_OPS)}")
        if not self.outputs:
            object.__setattr__(self, "outputs", (self.name,))


@dataclass
class Graph:
    nodes: List[Node]
    outputs: Tuple[str, ...]
    # lazily-built adjacency index shared by node_producing / consumers /
    # reaches. Passes never mutate a Graph in place (every rewrite builds a
    # fresh Graph), so the cache stays valid for the instance's lifetime.
    _idx: Optional[Tuple[Dict[str, Node], Dict[str, List[Node]],
                         Dict[str, Node]]] = field(
        default=None, init=False, repr=False, compare=False)

    def _index(self):
        if self._idx is None:
            producer: Dict[str, Node] = {}
            consumers: Dict[str, List[Node]] = {}
            by_name: Dict[str, Node] = {}
            for n in self.nodes:
                by_name[n.name] = n
                for v in n.outputs:
                    producer[v] = n
                for v in n.inputs:
                    consumers.setdefault(v, []).append(n)
            self._idx = (producer, consumers, by_name)
        return self._idx

    def node_producing(self, value: str) -> Optional[Node]:
        return self._index()[0].get(value)

    def consumers(self, value: str) -> List[Node]:
        return list(self._index()[1].get(value, ()))

    def reaches(self, src: str, dst: str) -> bool:
        """Is there a dependency path from node `src` to node `dst`?
        O(V+E) per query over the shared adjacency index."""
        _, consumers_of, by_name = self._index()
        seen, stack = set(), [src]
        while stack:
            cur = stack.pop()
            if cur == dst:
                return True
            if cur in seen:
                continue
            seen.add(cur)
            for v in by_name[cur].outputs:
                stack.extend(c.name for c in consumers_of.get(v, ()))
        return False

    def validate(self) -> "Graph":
        """Raise :class:`GraphError` (naming the offender) on missing
        producers, duplicate producers, unknown graph outputs, or cycles."""
        _topo(self.nodes, self.outputs)
        return self


# ---------------------------------------------------------------------------
# Fusion passes
# ---------------------------------------------------------------------------


def _single_consumer(g: Graph, value: str,
                     allow_output: bool = False) -> Optional[Node]:
    """The unique consumer of `value`, or None. A value listed in the graph
    outputs counts as externally consumed unless ``allow_output`` (used when
    the fused op re-exposes the value, e.g. fused_rs_ln_ag's z output)."""
    cs = g.consumers(value)
    if not allow_output and value in g.outputs:
        return None
    return cs[0] if len(cs) == 1 else None


def fuse_compute_aware(g: Graph) -> Graph:
    """Pass 1: align collectives with the adjacent GEMM's memory semantics."""
    nodes = list(g.nodes)
    changed = True
    while changed:
        changed = False
        for n in nodes:
            if n.op == "allgather":
                c = _single_consumer(g, n.name)
                if c is not None and c.op == "gemm_col":
                    fused = Node(c.name, "ag_gemm", n.inputs, c.weights)
                    nodes = [x for x in nodes if x.name not in (n.name, c.name)]
                    nodes.append(fused)
                    g = Graph(_topo(nodes, g.outputs), g.outputs)
                    nodes = list(g.nodes)
                    changed = True
                    break
            if n.op == "gemm_row":
                c = _single_consumer(g, n.name)
                if c is not None and c.op in ("reduce_scatter", "allreduce"):
                    op = "gemm_rs" if c.op == "reduce_scatter" else "gemm_ar"
                    fused = Node(c.name, op, n.inputs, n.weights)
                    nodes = [x for x in nodes if x.name not in (n.name, c.name)]
                    nodes.append(fused)
                    g = Graph(_topo(nodes, g.outputs), g.outputs)
                    nodes = list(g.nodes)
                    changed = True
                    break
    return Graph(_topo(nodes, g.outputs), g.outputs)


def fuse_shared_gather(g: Graph) -> Graph:
    """Pass 1b: an ``allgather`` consumed by *several* ``gemm_col`` nodes
    (fused QKV, gate+up) becomes one ``ag_gemm_multi``: the activation
    circulates the ring once and every weight consumes each arriving chunk
    (the multi-weight pull alignment the hand-fused sub-layers used)."""
    nodes = list(g.nodes)
    for n in nodes:
        if n.op != "allgather" or n.name in g.outputs:
            continue
        cs = g.consumers(n.name)
        if len(cs) < 2 or any(c.op != "gemm_col" for c in cs):
            continue
        fused = Node("+".join(c.name for c in cs), "ag_gemm_multi",
                     n.inputs,
                     tuple(w for c in cs for w in c.weights),
                     outputs=tuple(c.name for c in cs))
        drop = {n.name} | {c.name for c in cs}
        nodes = [x for x in nodes if x.name not in drop] + [fused]
        return fuse_shared_gather(Graph(_topo(nodes, g.outputs), g.outputs))
    return g


def fuse_sublayer_chain(g: Graph) -> Graph:
    """Pass 2: gemm_rs → [add|residual] → layernorm → ag_gemm[_multi] ⇒ one
    pipeline. The post-add value may have *several* consumers (in a
    whole-block graph it feeds both the next LN and the next residual add;
    in a period graph the block→block seam looks the same): the fused op
    re-exposes it, so only the layernorm leg is swallowed.

    MoE variant: when the normed value feeds a ``route`` node instead of a
    gather (attention-out RS → residual → LN → router), the gather-less
    prefix fuses into ``fused_rs_ln``, which re-exposes BOTH the normed
    value (for route/unroute/dense-residual consumers) and z."""
    nodes = list(g.nodes)
    for rs in list(nodes):
        if rs.op != "gemm_rs":
            continue
        # rs's value may escape as a graph output — the fused op re-exposes it
        nxt = _single_consumer(g, rs.name, allow_output=True)
        residual = None
        add_node = None
        if nxt is not None and nxt.op in ("add", "residual"):
            if rs.name in g.outputs:
                # the fused op re-exposes only the post-add z — a graph that
                # also exports the pre-add value must keep the chain unfused
                continue
            other = [v for v in nxt.inputs if v != rs.name]
            residual = other[0] if other else None
            add_node = nxt
            # z = rs + residual is re-exposed by the fused op, so it may be a
            # graph output or feed several consumers — fuse along the (one)
            # layernorm among them
            lns = [c for c in g.consumers(nxt.name) if c.op == "layernorm"]
            nxt = lns[0] if len(lns) == 1 else None
        if nxt is None or nxt.op != "layernorm":
            continue
        ln = nxt
        ins = rs.inputs + ((residual,) if residual else ())
        z_name = (add_node or rs).name
        drop = {rs.name, ln.name} | ({add_node.name} if add_node else set())
        ag = _single_consumer(g, ln.name)
        if ag is not None and ag.op in ("ag_gemm", "ag_gemm_multi"):
            if ag.op == "ag_gemm":
                fused = Node(ag.name, "fused_rs_ln_ag", ins,
                             rs.weights + ln.weights + ag.weights,
                             outputs=(ag.name, z_name))
            else:
                fused = Node(ag.name, "fused_rs_ln_ag_multi", ins,
                             rs.weights + ln.weights + ag.weights,
                             outputs=ag.outputs + (z_name,))
            drop.add(ag.name)
        elif any(c.op == "route" for c in g.consumers(ln.name)):
            # the normed value feeds an expert router (and usually also the
            # unroute scatter / a dense-residual MLP) — fuse the RS + add +
            # norm and re-expose the normed value under its old name
            fused = Node(ln.name, "fused_rs_ln", ins,
                         rs.weights + ln.weights,
                         outputs=(ln.name, z_name))
        else:
            continue
        nodes = [x for x in nodes if x.name not in drop] + [fused]
        return fuse_sublayer_chain(Graph(_topo(nodes, g.outputs), g.outputs))
    return Graph(_topo(nodes, g.outputs), g.outputs)


def _input_ancestors(g: Graph, nodes: List[Node]) -> Dict[str, frozenset]:
    """Node name → the set of graph ``input`` nodes it transitively depends
    on. Two nodes belong to the same microbatch *chain* iff these sets
    intersect: merged microbatch fragments each hang off their own input
    (``mb{i}.x``), so cross-chain sets are disjoint while a fork inside one
    chain shares its input ancestor. ``nodes`` must be in topo order."""
    anc: Dict[str, frozenset] = {}
    for n in nodes:
        if n.op == "input":
            anc[n.name] = frozenset((n.name,))
            continue
        s = frozenset()
        for v in n.inputs:
            p = g.node_producing(v)
            if p is not None:
                s |= anc[p.name]
        anc[n.name] = s
    return anc


def asymmetric_candidates(g: Graph) -> List[Tuple[Node, Node]]:
    """Every legal pass-3 pair of ``g``, ranked nearest-independent-first.

    A candidate is a (gemm_rs, ag_gemm[_multi]) pair with no dependency path
    either way AND disjoint ``input``-ancestor sets (the chain-id guard: the
    overlap primitive runs its streams in lockstep, so two collectives fed
    by the same microbatch's data — dependency-free only because of a fork —
    must never pair). Ranking: topological distance, ties broken by earliest
    topo position and then by node names — the greedy pass takes the head of
    this list; the perfsim planner scores *alternative* orders.

    On a merged fwd+bwd TRAINING graph (one with ``d.*`` cotangent-seed
    inputs, see :func:`build_training_graph`) cross-direction pairs — a
    backward grad reduce-scatter against a forward(-recompute) gather, the
    T3-class overlap the paper targets — rank before same-direction pairs:
    pairing two forward nodes of different chains serializes one chain's
    whole backward behind the other's forward, while the cross pair is the
    schedule that hides the grad collective behind the next chain's
    forward. Forward-only graphs have no seeds, so their ranking (and every
    pre-training behaviour pinned on it) is unchanged."""
    nodes = _topo(list(g.nodes), g.outputs)
    order = {n.name: i for i, n in enumerate(nodes)}
    chain = _input_ancestors(g, nodes)
    seeds = frozenset(n.name for n in nodes
                      if n.op == "input" and n.name.startswith(_D_PREFIX))

    def is_bwd(name: str) -> bool:
        return bool(chain[name] & seeds)

    cands = []
    for a in nodes:
        if a.op != "gemm_rs":
            continue
        for b in nodes:
            if b.op not in ("ag_gemm", "ag_gemm_multi") or b.name == a.name:
                continue
            if chain[a.name] & chain[b.name]:
                continue
            if g.reaches(a.name, b.name) or g.reaches(b.name, a.name):
                continue
            key = (0 if seeds and is_bwd(a.name) != is_bwd(b.name) else 1,
                   abs(order[a.name] - order[b.name]),
                   min(order[a.name], order[b.name]), a.name, b.name)
            cands.append((key, a, b))
    cands.sort(key=lambda t: t[0])
    return [(a, b) for _, a, b in cands]


def apply_pair(g: Graph, a: Node, b: Node) -> Graph:
    """Fuse one (gemm_rs, ag_gemm[_multi]) candidate into ``overlap_asym``."""
    fused = Node(f"{a.name}+{b.name}", "overlap_asym",
                 a.inputs + b.inputs, a.weights + b.weights,
                 outputs=(a.name,) + b.outputs)
    nodes = [x for x in g.nodes if x.name not in (a.name, b.name)]
    nodes.append(fused)
    return Graph(_topo(nodes, g.outputs), g.outputs)


def pair_asymmetric(g: Graph,
                    pairing: Optional[Sequence[Tuple[str, str]]] = None
                    ) -> Graph:
    """Pass 3: co-schedule independent gemm_rs + ag_gemm[_multi] pairs so
    their complementary ring directions share the links each step (e.g. one
    microbatch's FFN-out RS against another's attention-in gather).

    Default policy (deterministic, nearest-independent-pair-first): fuse the
    head of :func:`asymmetric_candidates` and repeat until no independent
    pair remains — a merged microbatch/period graph co-schedules the
    *adjacent* seam (chain k's FFN-out RS with the nearest independent
    attention gather of chain k+1) rather than an arbitrary first match.

    With an explicit ``pairing`` — an ordered sequence of (gemm_rs name,
    ag_gemm name) — the pass instead applies exactly those pairs, in order
    (a planner decision, see :mod:`repro.plan.search`). Each named pair must
    still be a legal candidate when its turn comes (earlier fusions change
    the dependency structure); an illegal pair raises :class:`GraphError`
    so a stale cached plan fails loudly rather than silently reordering."""
    if pairing is not None:
        for rs_name, ag_name in pairing:
            cand = {(a.name, b.name): (a, b)
                    for a, b in asymmetric_candidates(g)}
            if (rs_name, ag_name) not in cand:
                raise GraphError(
                    f"planner pairing ({rs_name!r}, {ag_name!r}) is not a "
                    f"legal independent pair of this graph")
            g = apply_pair(g, *cand[(rs_name, ag_name)])
        return g
    cands = asymmetric_candidates(g)
    if not cands:
        return Graph(_topo(list(g.nodes), g.outputs), g.outputs)
    return pair_asymmetric(apply_pair(g, *cands[0]))


def optimize(g: Graph, asymmetric: bool = True, planner=None) -> Graph:
    """Run passes 1 → 1b → 2 → 3. ``planner`` drives pass 3's pairing order:

    - ``None`` / ``"greedy"`` — the deterministic nearest-independent-first
      policy (the default, unchanged behaviour);
    - ``"perfsim"`` — a :class:`repro.plan.search.PerfsimPlanner` with
      synthesized shapes: candidate pairings are scored by simulated
      makespan over the perfsim cost model and the argmin wins;
    - any object with a ``pair(g) -> Graph`` method — e.g. a PerfsimPlanner
      carrying the real shapes/topology (the ``tp.sp_period`` path).
    """
    g = fuse_compute_aware(g)
    g = fuse_shared_gather(g)
    g = fuse_sublayer_chain(g)
    if asymmetric:
        if planner is None or planner == "greedy":
            g = pair_asymmetric(g)
        else:
            if planner == "perfsim":
                from repro.plan import PerfsimPlanner
                planner = PerfsimPlanner()
            g = planner.pair(g)
    return g


def _topo(nodes: List[Node], outputs) -> List[Node]:
    """Stable topological order by value availability.

    Raises :class:`GraphError` naming the offending node/value on duplicate
    producers, unknown graph outputs, inputs with no producer, or cycles."""
    produced: Dict[str, str] = {}
    for n in nodes:
        for v in n.outputs:
            if v in produced and produced[v] != n.name:
                raise GraphError(
                    f"value {v!r} is produced by both node {produced[v]!r} "
                    f"and node {n.name!r}")
            produced[v] = n.name
    for o in outputs:
        if o not in produced:
            raise GraphError(
                f"graph output {o!r} is not produced by any node")
    avail = set()
    ordered = [n for n in nodes if n.op == "input"]
    for n in ordered:
        avail |= set(n.outputs)
    pending = [n for n in nodes if n.op != "input"]
    while pending:
        ready = [n for n in pending if all(v in avail for v in n.inputs)]
        if not ready:
            # stalled — diagnose: a consumed value nobody produces, or a cycle
            for n in pending:
                missing = [v for v in n.inputs if v not in produced]
                if missing:
                    raise GraphError(
                        f"node {n.name!r} consumes value {missing[0]!r}, "
                        f"which no node produces")
            cyc = ", ".join(sorted(n.name for n in pending))
            raise GraphError(
                f"cycle in dataflow graph involving nodes: {cyc}")
        ready_ids = {id(n) for n in ready}
        for n in ready:
            ordered.append(n)
            avail |= set(n.outputs)
        pending = [n for n in pending if id(n) not in ready_ids]
    return ordered


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


def execute(g: Graph, values: Dict[str, jnp.ndarray],
            weights: Dict[str, jnp.ndarray], axis: Optional[str] = None,
            cais: CAISConfig = CAISConfig(), norm: str = "rmsnorm",
            backend=None):
    """Evaluate the graph. With ``axis`` set this must run inside shard_map
    (values/weights are local shards per the layout conventions) and every
    fused collective op dispatches through ``backend`` — a
    :class:`repro.core.backends.CollectiveBackend` instance or registry name
    (default ``"cais"``). ``axis`` may be the composite ``("tp_in",
    "tp_out")`` tuple of a hierarchical 2D mesh — fused ops thread it to the
    backend's hierarchical compositions; raw allgather/reduce_scatter nodes
    compose per-tier here (inter-node gather first / intra-node scatter
    first, matching the tp_in-major shard order). Without ``axis``,
    collectives degenerate to identity/plain math (single-device
    reference)."""
    from repro.core.backends import get_backend
    from repro.models.layers import apply_norm

    env = dict(values)
    dist = axis is not None
    hier = isinstance(axis, (tuple, list)) and len(axis) > 1
    be = get_backend(backend if backend is not None else "cais")

    for n in g.nodes:
        if n.op == "input":
            continue
        ins = [env[v] for v in n.inputs]
        ws = [weights[k] for k in n.weights]
        if n.op == "gemm_col" or n.op == "gemm_row":
            env[n.name] = ins[0] @ ws[0]
        elif n.op == "allgather":
            if dist and hier:
                out = jax.lax.all_gather(ins[0], axis[-1], axis=1, tiled=True)
                env[n.name] = jax.lax.all_gather(out, axis[0], axis=1,
                                                 tiled=True)
            else:
                env[n.name] = (jax.lax.all_gather(ins[0], axis, axis=1,
                                                  tiled=True)
                               if dist else ins[0])
        elif n.op == "reduce_scatter":
            if dist and hier:
                out = jax.lax.psum_scatter(ins[0], axis[0],
                                           scatter_dimension=1, tiled=True)
                env[n.name] = jax.lax.psum_scatter(out, axis[-1],
                                                   scatter_dimension=1,
                                                   tiled=True)
            else:
                env[n.name] = (jax.lax.psum_scatter(ins[0], axis,
                                                    scatter_dimension=1,
                                                    tiled=True)
                               if dist else ins[0])
        elif n.op == "allreduce":
            env[n.name] = jax.lax.psum(ins[0], axis) if dist else ins[0]
        elif n.op == "layernorm":
            env[n.name] = apply_norm(norm, {"scale": ws[0]}, ins[0])
        elif n.op in ("add", "residual"):
            env[n.name] = ins[0] + ins[1]
        elif n.op in _FN_OPS:
            res = n.fn(*ins, *ws)
            if len(n.outputs) > 1:
                for name, val in zip(n.outputs, res):
                    env[name] = val
            else:
                env[n.outputs[0]] = res
        elif n.op == "a2a_ffn":
            fn = (lambda chunk, _n=n, _ws=tuple(ws): _n.fn(chunk, *_ws))
            env[n.name] = (be.a2a_expert_ffn(ins[0], fn, axis, cais)
                           if dist else jax.vmap(fn)(ins[0]))
        elif n.op == "ag_gemm":
            env[n.name] = (be.ag_gemm(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "ag_gemm_multi":
            outs = (be.ag_gemm_multi(ins[0], tuple(ws), axis, cais)
                    if dist else tuple(ins[0] @ w for w in ws))
            for name, val in zip(n.outputs, outs):
                env[name] = val
        elif n.op == "gemm_rs":
            env[n.name] = (be.gemm_rs(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "bwd_ag_gemm":
            # adjoint of gemm_rs: gather the seq-sharded cotangent, GEMM with
            # the transposed weight; the gathered cotangent is re-exposed for
            # the weight-gradient GEMM (outputs (d_x, dy_full))
            dx_, dyf = (be.grad_ag_gemm(ins[0], ws[0], axis, cais)
                        if dist else (ins[0] @ ws[0], ins[0]))
            env[n.outputs[0]], env[n.outputs[1]] = dx_, dyf
        elif n.op == "bwd_a2a_ffn":
            # adjoint of a2a_ffn: re-dispatch (send-row, cotangent-row)
            # pairs to the expert owners, per-row VJP of the expert fn
            # there, return d(recv) to the senders; the owner keeps its
            # local expert-weight grads. outputs = (d_send, dw...)
            def _row_vjp(chunk, gyc, _n=n, _ws=tuple(ws)):
                _, pull = jax.vjp(lambda c, *w: _n.fn(c, *w), chunk, *_ws)
                gr = pull(gyc)
                return gr[0], tuple(gr[1:])
            if dist:
                dsend, dws_ = be.grad_a2a_expert_ffn(ins[0], ins[1],
                                                     _row_vjp, axis, cais)
            else:
                d_rows, dw_rows = jax.vmap(_row_vjp)(ins[0], ins[1])
                dsend = d_rows
                dws_ = tuple(jnp.sum(a, axis=0) for a in dw_rows)
            for name, val in zip(n.outputs, (dsend,) + tuple(dws_)):
                env[name] = val
        elif n.op == "gemm_ar":
            env[n.name] = (be.gemm_ar(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "fused_rs_ln_ag":
            w1, scale, w2 = ws
            res = env[n.inputs[1]] if len(n.inputs) > 1 else None
            if dist:
                out, z = be.fused_rs_ln_ag(ins[0], w1, scale, w2, axis,
                                           cais, norm=norm, residual=res)
            else:
                z = ins[0] @ w1
                if res is not None:
                    z = z + res
                out = apply_norm(norm, {"scale": scale}, z) @ w2
            env[n.outputs[0]], env[n.outputs[1]] = out, z
        elif n.op == "fused_rs_ln_ag_multi":
            w1, scale = ws[0], ws[1]
            ws2 = tuple(ws[2:])
            res = env[n.inputs[1]] if len(n.inputs) > 1 else None
            if dist:
                outs, z = be.fused_rs_ln_ag_multi(ins[0], w1, scale, ws2,
                                                  axis, cais, norm=norm,
                                                  residual=res)
            else:
                z = ins[0] @ w1
                if res is not None:
                    z = z + res
                zn = apply_norm(norm, {"scale": scale}, z)
                outs = tuple(zn @ w for w in ws2)
            for name, val in zip(n.outputs, outs + (z,)):
                env[name] = val
        elif n.op == "fused_rs_ln":
            w1, scale = ws
            res = env[n.inputs[1]] if len(n.inputs) > 1 else None
            if dist:
                zn, z = be.fused_rs_ln(ins[0], w1, scale, axis, cais,
                                       norm=norm, residual=res)
            else:
                z = ins[0] @ w1
                if res is not None:
                    z = z + res
                zn = apply_norm(norm, {"scale": scale}, z)
            env[n.outputs[0]], env[n.outputs[1]] = zn, z
        elif n.op == "overlap_asym":
            w_rs = ws[0]
            ag_ws = tuple(ws[1:])
            w_ag = ag_ws if len(ag_ws) > 1 else ag_ws[0]
            if dist:
                rs_out, ag_out = be.overlap_asymmetric(
                    (ins[0], w_rs), (ins[1], w_ag), axis, cais)
            else:
                rs_out = ins[0] @ w_rs
                ag_out = (tuple(ins[1] @ w for w in ag_ws)
                          if len(ag_ws) > 1 else ins[1] @ ag_ws[0])
            ag_outs = ag_out if isinstance(ag_out, tuple) else (ag_out,)
            for name, val in zip(n.outputs, (rs_out,) + ag_outs):
                env[name] = val
        else:
            raise ValueError(n.op)
    return tuple(env[o] for o in g.outputs)


# ---------------------------------------------------------------------------
# Canonical sub-layer graphs (paper Fig. 12, L1–L4)
# ---------------------------------------------------------------------------


def sublayer_graph() -> Graph:
    """[GEMM (row) → RS] → LN → [AG → GEMM (col)] — the L1–L4 shape:
    e.g. L2 = second FFN layer → LayerNorm → input projection."""
    return Graph(
        nodes=[
            Node("x", "input"),
            Node("g1", "gemm_row", ("x",), ("w1",)),
            Node("rs", "reduce_scatter", ("g1",)),
            Node("ln", "layernorm", ("rs",), ("scale",)),
            Node("ag", "allgather", ("ln",)),
            Node("g2", "gemm_col", ("ag",), ("w2",)),
        ],
        outputs=("g2",),
    )


def merge_graphs(graphs: Sequence[Graph],
                 prefixes: Optional[Sequence[str]] = None,
                 share_weights: bool = False) -> Graph:
    """Disjoint union of several graphs with value/node renaming — e.g. two
    microbatches of the same transformer block, or consecutive *different*
    blocks of a period, so cross-graph passes (``pair_asymmetric``) can
    co-schedule collectives across them.

    Weight keys are prefixed exactly like values by default, so merging
    graphs of different blocks cannot silently alias ``wq``/``w_up``/…
    across blocks. Pass ``share_weights=True`` for the same-params
    microbatch case: weight keys are left unrenamed and every merged copy
    reads one shared weights dict. Duplicate prefixes would make the
    renaming collide (unintended weight-key/value aliasing) and raise
    :class:`GraphError` up front."""
    if prefixes is None:
        prefixes = [f"mb{i}." for i in range(len(graphs))]
    if len(prefixes) != len(graphs):
        raise GraphError(
            f"merge_graphs got {len(graphs)} graphs but "
            f"{len(prefixes)} prefixes")
    if len(set(prefixes)) != len(prefixes):
        dup = sorted(p for p in set(prefixes) if list(prefixes).count(p) > 1)
        raise GraphError(
            f"merge_graphs got duplicate prefix {dup[0]!r}: node and weight "
            f"renaming would collide across the merged graphs")
    nodes: List[Node] = []
    outs: List[str] = []
    for g, p in zip(graphs, prefixes):
        for n in g.nodes:
            nodes.append(dataclasses.replace(
                n, name=p + n.name,
                inputs=tuple(p + v for v in n.inputs),
                outputs=tuple(p + v for v in n.outputs),
                weights=(n.weights if share_weights
                         else tuple(p + w for w in n.weights))))
        outs.extend(p + o for o in g.outputs)
    return Graph(nodes, tuple(outs))


def dual_sublayer_graph() -> Graph:
    """Two independent sub-chains (e.g. two microbatches / fwd+bwd): the
    optimizer pairs the RS of one with the AG-GEMM of the other."""
    return Graph(
        nodes=[
            Node("xa", "input"),
            Node("xb", "input"),
            Node("ga", "gemm_row", ("xa",), ("wa",)),
            Node("rsa", "reduce_scatter", ("ga",)),
            Node("agb", "allgather", ("xb",)),
            Node("gb", "gemm_col", ("agb",), ("wb",)),
        ],
        outputs=("rsa", "gb"),
    )


# ---------------------------------------------------------------------------
# Backward: training graphs (declared adjoints per fused forward op)
# ---------------------------------------------------------------------------
#
# build_training_graph takes a forward graph that has been through passes
# 1/1b/2 (NOT pass 3 — overlap_asym has no adjoint; the caller runs pass 3
# on the *merged* result so it can pair forward against backward
# collectives) and appends adjoint nodes in reverse topological order. The
# builder works at the fused-op level on purpose: pass 2 re-exposes every
# activation the adjoints need (z, the normed value is recomputable from z,
# q/k/v/o/h are plain graph values), whereas differentiating the primitive
# graph would hang extra non-gemm consumers off every allgather and block
# passes 1b/2 from fusing the forward at all.
#
# Derived weight keys: adjoints reference transposed (and, for shared
# gathers, concatenated) forward weights as new keys ``"w^T"`` /
# ``"wa+wb^T"``. These are *local-shard* transforms — the transpose of a
# column shard IS that device's shard of the row-sharded transpose — so
# :func:`derived_weights` materializes them inside shard_map from the local
# forward shards, with no extra mesh arguments.

_D_PREFIX = "d."
_DW_PREFIX = "dw."


def grad_input_name(value: str) -> str:
    """Name of the ``input`` node seeding the cotangent of forward output
    ``value`` in a training graph."""
    return _D_PREFIX + value


def supports_backward(g: Graph) -> bool:
    """True iff every node's op has a declared adjoint (:data:`ADJOINTS`) —
    every op the model builders leave in a period graph after passes
    1/1b/2: the dense vocabulary, MoE routing (``route``/``a2a_ffn``/
    ``unroute``), ``gemm_ar``/``gemm_col`` (ragged/decode and
    sequence-parallel-off layouts). Raw collectives and pass-3
    ``overlap_asym`` have none; callers keep JAX autodiff of the executed
    forward graph for those (``sp_period`` warns once when that fallback
    fires under ``graph_backward=True``)."""
    return all(n.op in ADJOINTS for n in g.nodes)


@dataclass(frozen=True)
class TrainingGraph:
    """A forward graph with its graph-built backward appended.

    ``graph.outputs`` = the input cotangents (one per forward ``input``
    node that gradients reach, in forward declaration order) followed by
    every per-use weight-gradient value. ``dweights`` groups the latter by
    forward weight key: shared-weight chains (microbatch copies of one
    block) each contribute one value per use, and the caller sums each
    group (then psums replicated-weight grads over the mesh)."""
    graph: Graph
    grad_inputs: Tuple[str, ...]          # cotangent seeds ("d." + output)
    dx: Dict[str, str]                    # fwd input value -> grad value
    dweights: Dict[str, Tuple[str, ...]]  # weight key -> grad values (sum)


def _norm_vjp(norm: str) -> Callable:
    def vjp_fn(x, gy, scale):
        from repro.models.layers import apply_norm
        _, pull = jax.vjp(
            lambda xx, ss: apply_norm(norm, {"scale": ss}, xx), x, scale)
        return pull(gy)          # (d_x, d_scale)
    return vjp_fn


def _norm_fwd(norm: str) -> Callable:
    def fwd_fn(x, scale):
        from repro.models.layers import apply_norm
        return apply_norm(norm, {"scale": scale}, x)
    return fwd_fn


def _fn_vjp(fn: Callable, k_in: int, k_w: int,
            mask: Tuple[bool, ...]) -> Callable:
    """Adjoint of a ``custom`` node's fn. Called as
    ``vjp(*fwd_inputs, *present_cotangents, *fwd_weights)`` (the executor's
    ``fn(*ins, *ws)`` convention); absent cotangents (outputs no gradient
    reaches) are zero-filled against the recomputed primals."""
    def vjp_fn(*args):
        prim = args[:k_in]
        cots = args[k_in:len(args) - k_w] if k_w else args[k_in:]
        ws = args[len(args) - k_w:] if k_w else ()
        outs, pull = jax.vjp(fn, *prim, *ws)
        it = iter(cots)
        if len(mask) == 1:
            cot = next(it)
        else:
            cot = tuple(next(it) if m else jnp.zeros_like(o)
                        for m, o in zip(mask, outs))
        grads = pull(cot)        # cotangents for (inputs..., weights...)
        return grads if len(grads) > 1 else grads[0]
    return vjp_fn


def _concat_last(*gs):
    return jnp.concatenate(gs, axis=-1)


def _dw(act, gout):
    """Per-use weight gradient: contract activation (B, S, in) against the
    output cotangent (B, S, out) over batch×seq → (in, out)."""
    return jnp.einsum("bsi,bsj->ij", act, gout)


def _gemm_t(gy, wT):
    """dx leg of a plain GEMM adjoint: cotangent @ transposed weight."""
    return gy @ wT


def build_training_graph(g: Graph, norm: str = "rmsnorm") -> TrainingGraph:
    """Append the graph-built backward to forward graph ``g`` (which must be
    post-pass-1/1b/2 and pre-pass-3; see the section comment above).

    Every forward output gets a cotangent seed ``input`` node
    (:func:`grad_input_name`); adjoints are emitted per the declared
    :data:`ADJOINTS` vocabulary in reverse topo order, accumulating fan-out
    gradients through ``add`` nodes. The result is ONE graph containing
    both directions — run :func:`optimize` on it so pass 3 can pair a
    backward grad reduce-scatter against an independent chain's forward
    gather (the fwd/bwd cross-chain ``overlap_asym`` the paper targets)."""
    bad = sorted({n.op for n in g.nodes if n.op not in ADJOINTS})
    if bad:
        raise GraphError(
            f"no declared adjoint for op {bad[0]!r}; gate on "
            f"supports_backward() and fall back to JAX autodiff")
    fwd = _topo(list(g.nodes), g.outputs)
    nodes: List[Node] = list(fwd)
    contrib: Dict[str, List[str]] = {}
    dweights: Dict[str, List[str]] = {}
    grad_inputs = tuple(grad_input_name(o) for o in g.outputs)
    for o, gi in zip(g.outputs, grad_inputs):
        nodes.append(Node(gi, "input"))
        contrib.setdefault(o, []).append(gi)

    def finalize(v: str) -> Optional[str]:
        # sum the contributions to d(v); None if no gradient reaches v
        parts = contrib.get(v)
        if not parts:
            return None
        acc = parts[0]
        for i, p in enumerate(parts[1:]):
            nm = f"dsum{i}.{v}"
            nodes.append(Node(nm, "add", (acc, p)))
            acc = nm
        return acc

    def take(v: str, grad: str) -> None:
        contrib.setdefault(v, []).append(grad)

    def add_dw(w: str, grad: str) -> None:
        dweights.setdefault(w, []).append(grad)

    def grad_rs(n: Node, gys: List[str], an: str, xn: str) -> str:
        # shared d(gathered-input) leg of ag_gemm[_multi] and the fused ops:
        # concat the per-weight cotangents and reduce-scatter them through
        # the transposed (concatenated) weight — the grad reduce-scatter
        if len(gys) > 1:
            cat = f"dcat.{n.name}"
            nodes.append(Node(f"adj.cat.{n.name}", "custom", tuple(gys),
                              outputs=(cat,), fn=_concat_last))
        else:
            cat = gys[0]
        out = f"d.{xn}@{an}"
        nodes.append(Node(out, "gemm_rs", (cat,),
                          ("+".join(n.weights[-len(gys):]) + "^T",)))
        return out

    dx: Dict[str, str] = {}
    for n in reversed(fwd):
        an = f"adj.{n.name}"
        if n.op == "input":
            dxv = finalize(n.name)
            if dxv is not None:
                dx[n.name] = dxv
        elif n.op in ("add", "residual"):
            gy = finalize(n.name)
            if gy is not None:
                for v in n.inputs:
                    take(v, gy)
        elif n.op == "layernorm":
            gy = finalize(n.name)
            if gy is None:
                continue
            xin, scale = n.inputs[0], n.weights[0]
            nodes.append(Node(
                an, "custom", (xin, gy), (scale,),
                outputs=(f"d.{xin}@{an}", f"{_DW_PREFIX}{an}.{scale}"),
                fn=_norm_vjp(norm)))
            take(xin, f"d.{xin}@{an}")
            add_dw(scale, f"{_DW_PREFIX}{an}.{scale}")
        elif n.op in _FN_OPS:
            # custom, route, unroute: jax.vjp of the node's local fn. For
            # route the output triple is (send, combine, aux) — the aux
            # load-balance statistic is a first-class graph output, so its
            # cotangent (seeded from d.<aux>) rides the same VJP into the
            # router-logit gradients.
            gys = [finalize(v) for v in n.outputs]
            if all(q is None for q in gys):
                continue
            have = tuple(q for q in gys if q is not None)
            mask = tuple(q is not None for q in gys)
            outs = (tuple(f"d.{v}@{an}" for v in n.inputs)
                    + tuple(f"{_DW_PREFIX}{an}.{w}" for w in n.weights))
            nodes.append(Node(
                an, "custom", n.inputs + have, n.weights, outputs=outs,
                fn=_fn_vjp(n.fn, len(n.inputs), len(n.weights), mask)))
            for v in n.inputs:
                take(v, f"d.{v}@{an}")
            for w in n.weights:
                add_dw(w, f"{_DW_PREFIX}{an}.{w}")
        elif n.op == "a2a_ffn":
            gy = finalize(n.name)
            if gy is None:
                continue
            sn = n.inputs[0]
            dsend = f"d.{sn}@{an}"
            dw_names = tuple(f"{_DW_PREFIX}{an}.{w}" for w in n.weights)
            nodes.append(Node(an, "bwd_a2a_ffn", (sn, gy), n.weights,
                              outputs=(dsend,) + dw_names, fn=n.fn))
            take(sn, dsend)
            for w, dwn in zip(n.weights, dw_names):
                add_dw(w, dwn)
        elif n.op in ("ag_gemm", "ag_gemm_multi"):
            gys = [finalize(v) for v in n.outputs]
            if all(q is None for q in gys):
                continue
            if any(q is None for q in gys):
                raise GraphError(
                    f"partial cotangents for shared gather {n.name!r}: "
                    f"every output of an ag_gemm_multi must be consumed")
            xn = n.inputs[0]
            take(xn, grad_rs(n, gys, an, xn))
            # weight grads re-gather the seq-sharded input (Megatron-style
            # recompute of the gathered activation — a raw IR allgather so
            # the planner sees and costs it)
            xg = f"xg.{n.name}"
            nodes.append(Node(xg, "allgather", (xn,)))
            for w, gy in zip(n.weights, gys):
                nodes.append(Node(f"adj.dw.{n.name}.{w}", "custom",
                                  (xg, gy),
                                  outputs=(f"{_DW_PREFIX}{an}.{w}",),
                                  fn=_dw))
                add_dw(w, f"{_DW_PREFIX}{an}.{w}")
        elif n.op == "gemm_rs":
            gy = finalize(n.name)
            if gy is None:
                continue
            hin, w1 = n.inputs[0], n.weights[0]
            dh, dyf = f"d.{hin}@{an}", f"dfull.{n.name}"
            nodes.append(Node(an, "bwd_ag_gemm", (gy,), (w1 + "^T",),
                              outputs=(dh, dyf)))
            take(hin, dh)
            nodes.append(Node(f"adj.dw.{n.name}.{w1}", "custom",
                              (hin, dyf),
                              outputs=(f"{_DW_PREFIX}{an}.{w1}",), fn=_dw))
            add_dw(w1, f"{_DW_PREFIX}{an}.{w1}")
        elif n.op == "gemm_ar":
            # y = psum(x_feat @ w_row) is replicated, so the adjoint is
            # purely local: dx = dy @ w^T lands feature-sharded, dw is the
            # local row-shard's contraction — no collective either way
            # (decode/ragged S, incl. S=1: nothing here depends on S).
            gy = finalize(n.name)
            if gy is None:
                continue
            hin, w1 = n.inputs[0], n.weights[0]
            dh = f"d.{hin}@{an}"
            nodes.append(Node(an, "custom", (gy,), (w1 + "^T",),
                              outputs=(dh,), fn=_gemm_t))
            take(hin, dh)
            nodes.append(Node(f"adj.dw.{n.name}.{w1}", "custom",
                              (hin, gy),
                              outputs=(f"{_DW_PREFIX}{an}.{w1}",), fn=_dw))
            add_dw(w1, f"{_DW_PREFIX}{an}.{w1}")
        elif n.op == "gemm_col":
            # sequence-parallel-off layout: x is replicated, y = x @ w_col
            # is feature-sharded. dx needs the cross-shard sum — emitted as
            # a backward ``gemm_ar`` (grad allreduce through w^T, dispatched
            # via the backend); dw is local per column shard.
            gy = finalize(n.name)
            if gy is None:
                continue
            xin, w1 = n.inputs[0], n.weights[0]
            dxv = f"d.{xin}@{an}"
            nodes.append(Node(dxv, "gemm_ar", (gy,), (w1 + "^T",)))
            take(xin, dxv)
            nodes.append(Node(f"adj.dw.{n.name}.{w1}", "custom",
                              (xin, gy),
                              outputs=(f"{_DW_PREFIX}{an}.{w1}",), fn=_dw))
            add_dw(w1, f"{_DW_PREFIX}{an}.{w1}")
        elif n.op in ("fused_rs_ln_ag", "fused_rs_ln_ag_multi"):
            gs, z = n.outputs[:-1], n.outputs[-1]
            gys = [finalize(v) for v in gs]
            dz_ext = finalize(z)
            if all(q is None for q in gys) and dz_ext is None:
                continue
            if any(q is None for q in gys):
                raise GraphError(
                    f"partial cotangents for fused seam {n.name!r}: every "
                    f"gather output must be consumed")
            hin = n.inputs[0]
            res = n.inputs[1] if len(n.inputs) > 1 else None
            w1, scale = n.weights[0], n.weights[1]
            # d(zn): the grad reduce-scatter through the w2 leg
            dzn = grad_rs(n, gys, an, f"zn.{n.name}")
            # norm VJP: d(z) from d(zn) (needs z, re-exposed by pass 2)
            dz_n, dscale = f"dznorm.{n.name}", f"{_DW_PREFIX}{an}.{scale}"
            nodes.append(Node(f"adj.ln.{n.name}", "custom", (z, dzn),
                              (scale,), outputs=(dz_n, dscale),
                              fn=_norm_vjp(norm)))
            add_dw(scale, dscale)
            if dz_ext is not None:
                dz = f"dz.{n.name}"
                nodes.append(Node(dz, "add", (dz_n, dz_ext)))
            else:
                dz = dz_n
            if res is not None:
                take(res, dz)
            # grad all-gather back through the RS leg
            dh, dyf = f"d.{hin}@{an}", f"dfull.{n.name}"
            nodes.append(Node(an, "bwd_ag_gemm", (dz,), (w1 + "^T",),
                              outputs=(dh, dyf)))
            take(hin, dh)
            nodes.append(Node(f"adj.dw.{n.name}.{w1}", "custom",
                              (hin, dyf),
                              outputs=(f"{_DW_PREFIX}{an}.{w1}",), fn=_dw))
            add_dw(w1, f"{_DW_PREFIX}{an}.{w1}")
            # w2 grads: recompute zn from the re-exposed z, re-gather it
            znr, zg = f"znr.{n.name}", f"zg.{n.name}"
            nodes.append(Node(znr, "custom", (z,), (scale,),
                              fn=_norm_fwd(norm)))
            nodes.append(Node(zg, "allgather", (znr,)))
            for w, gy in zip(n.weights[2:], gys):
                nodes.append(Node(f"adj.dw.{n.name}.{w}", "custom",
                                  (zg, gy),
                                  outputs=(f"{_DW_PREFIX}{an}.{w}",),
                                  fn=_dw))
                add_dw(w, f"{_DW_PREFIX}{an}.{w}")
        elif n.op == "fused_rs_ln":
            # the MoE router seam (no trailing gather): outputs (zn, z).
            # d(zn) arrives from the route/unroute/dense-residual adjoints,
            # d(z) from the next block's residual skip; norm VJP joins them
            # and the RS leg's adjoint (bwd_ag_gemm) carries dz back.
            znv, z = n.outputs
            dzn = finalize(znv)
            dz_ext = finalize(z)
            if dzn is None and dz_ext is None:
                continue
            hin = n.inputs[0]
            res = n.inputs[1] if len(n.inputs) > 1 else None
            w1, scale = n.weights[0], n.weights[1]
            if dzn is not None:
                dz_n = f"dznorm.{n.name}"
                dscale = f"{_DW_PREFIX}{an}.{scale}"
                nodes.append(Node(f"adj.ln.{n.name}", "custom", (z, dzn),
                                  (scale,), outputs=(dz_n, dscale),
                                  fn=_norm_vjp(norm)))
                add_dw(scale, dscale)
                if dz_ext is not None:
                    dz = f"dz.{n.name}"
                    nodes.append(Node(dz, "add", (dz_n, dz_ext)))
                else:
                    dz = dz_n
            else:
                dz = dz_ext
            if res is not None:
                take(res, dz)
            dh, dyf = f"d.{hin}@{an}", f"dfull.{n.name}"
            nodes.append(Node(an, "bwd_ag_gemm", (dz,), (w1 + "^T",),
                              outputs=(dh, dyf)))
            take(hin, dh)
            nodes.append(Node(f"adj.dw.{n.name}.{w1}", "custom",
                              (hin, dyf),
                              outputs=(f"{_DW_PREFIX}{an}.{w1}",), fn=_dw))
            add_dw(w1, f"{_DW_PREFIX}{an}.{w1}")
        else:  # pragma: no cover — ADJOINTS gate above is exhaustive
            raise GraphError(f"unhandled adjoint for op {n.op!r}")

    fwd_inputs = [n.name for n in fwd if n.op == "input"]
    dx_outs = tuple(dx[v] for v in fwd_inputs if v in dx)
    dw_outs = tuple(v for vals in dweights.values() for v in vals)
    tg = Graph(nodes, dx_outs + dw_outs).validate()
    return TrainingGraph(tg, grad_inputs, dx,
                         {k: tuple(v) for k, v in dweights.items()})


def derived_weight_keys(g: Graph) -> List[str]:
    """The transposed/concatenated weight keys (suffix ``"^T"``) a training
    graph references beyond the forward weights, in first-use order."""
    seen, out = set(), []
    for n in g.nodes:
        for w in n.weights:
            if w.endswith("^T") and w not in seen:
                seen.add(w)
                out.append(w)
    return out


def derived_weights(g: Graph, weights: Dict) -> Dict:
    """Extend ``weights`` with the derived keys of ``g``: ``"w^T"`` is the
    (local-shard) transpose of ``weights["w"]``; ``"a+b^T"`` concatenates
    the named shards along their last axis first (the shared-gather layout)
    then transposes. Local transforms only — valid inside shard_map."""
    out = dict(weights)
    for key in derived_weight_keys(g):
        parts = key[:-2].split("+")
        w = (out[parts[0]] if len(parts) == 1 else
             jnp.concatenate([out[p] for p in parts], axis=-1))
        out[key] = w.T if hasattr(w, "T") else w
    return out


def derived_weight_shapes(g: Graph, shapes: Dict) -> Dict:
    """Shape-level twin of :func:`derived_weights` for the planner: maps the
    derived keys to (out, in)-transposed / concat-then-transposed shapes."""
    out = dict(shapes)
    for key in derived_weight_keys(g):
        parts = key[:-2].split("+")
        d = out[parts[0]][0]
        f = sum(out[p][-1] for p in parts)
        out[key] = (f, d)
    return out
