"""Graph-level dataflow optimizer (paper §III-C).

A small dataflow IR over TP sub-layer chains plus a fusion pass that:

  1. fuses ``gemm_row → reduce_scatter``  into push-aligned ``gemm_rs``
     and ``allgather → gemm_col``         into pull-aligned ``ag_gemm``
     (the compute-aware ISA alignment, §III-A);
  2. fuses ``gemm_rs → [add] → layernorm → ag_gemm`` chains into one
     ``fused_rs_ln_ag`` pipeline (deep kernel fusion, Fig. 9);
  3. pairs *independent* ``gemm_rs`` / ``ag_gemm`` nodes into an
     ``overlap_asym`` dual-stream op with complementary link directions
     (asymmetric kernel overlapping, Fig. 9e/10);
  4. merges an ``allgather`` feeding several ``gemm_col`` nodes into one
     ``ag_gemm_multi`` (QKV / gate+up share a single ring circulation).

The executor runs a graph either as pure math (no mesh; reference) or inside
``shard_map`` (explicit TP), dispatching every fused collective op through a
:class:`repro.core.backends.CollectiveBackend` — the model sub-layers
(``repro.core.tp.sp_ffn`` / ``sp_attention``) are built, optimized, and run
through this IR. Tensor layout conventions per value:
``seq`` (B, S_loc, d) sequence-sharded · ``feat`` (B, S, d_loc)
feature-sharded · ``full`` (B, S, d) replicated.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.primitives import CAISConfig

# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------

#        op            inputs                weights         out layout
# input                ()                    —               declared
# gemm_col             (x: full)             w (d, F/n)      feat
# gemm_row             (x: feat)             w (d/n, F)      partial-full
# allgather            (x: seq)              —               full
# reduce_scatter       (x: partial-full)     —               seq
# allreduce            (x: partial-full)     —               full
# layernorm            (x: any)              scale (d,)      same
# add                  (a, b) same layout    —               same
# custom               (any...)              —               fn-defined
#   `fn` applies arbitrary *local* math (activation, attention core) — it
#   never touches the mesh, so fusion passes may move collectives around it
# --- fused (produced by optimize) ---
# ag_gemm              (x: seq)              w               feat
# ag_gemm_multi        (x: seq)              (w...)          feat per weight
# gemm_rs              (x: feat)             w               seq
# gemm_ar              (x: feat)             w               full
# fused_rs_ln_ag       (x: feat[, res:seq])  (w1, scale, w2) feat (+ seq z)
# overlap_asym         (x_rs: feat, x_ag: seq) (w_rs, w_ag)  (seq, feat)

VALID_OPS = {
    "input", "gemm_col", "gemm_row", "allgather", "reduce_scatter",
    "allreduce", "layernorm", "add", "custom",
    "ag_gemm", "ag_gemm_multi", "gemm_rs", "gemm_ar", "fused_rs_ln_ag",
    "overlap_asym",
}


@dataclass(frozen=True)
class Node:
    name: str
    op: str
    inputs: Tuple[str, ...] = ()
    weights: Tuple[str, ...] = ()   # keys into the weights dict
    outputs: Tuple[str, ...] = ()   # multi-output fused ops; default (name,)
    fn: Optional[Callable] = None   # local math for op == "custom"

    def __post_init__(self):
        assert self.op in VALID_OPS, self.op
        if not self.outputs:
            object.__setattr__(self, "outputs", (self.name,))


@dataclass
class Graph:
    nodes: List[Node]
    outputs: Tuple[str, ...]

    def node_producing(self, value: str) -> Optional[Node]:
        for n in self.nodes:
            if value in n.outputs:
                return n
        return None

    def consumers(self, value: str) -> List[Node]:
        return [n for n in self.nodes if value in n.inputs]

    def reaches(self, src: str, dst: str) -> bool:
        """Is there a dependency path from node `src` to node `dst`?
        O(V+E) per query: one adjacency build, one traversal."""
        by_name = {n.name: n for n in self.nodes}
        consumers_of: Dict[str, List[str]] = {}
        for n in self.nodes:
            for v in n.inputs:
                consumers_of.setdefault(v, []).append(n.name)
        seen, stack = set(), [src]
        while stack:
            cur = stack.pop()
            if cur == dst:
                return True
            if cur in seen:
                continue
            seen.add(cur)
            for v in by_name[cur].outputs:
                stack.extend(consumers_of.get(v, ()))
        return False


# ---------------------------------------------------------------------------
# Fusion passes
# ---------------------------------------------------------------------------


def _single_consumer(g: Graph, value: str,
                     allow_output: bool = False) -> Optional[Node]:
    """The unique consumer of `value`, or None. A value listed in the graph
    outputs counts as externally consumed unless ``allow_output`` (used when
    the fused op re-exposes the value, e.g. fused_rs_ln_ag's z output)."""
    cs = g.consumers(value)
    if not allow_output and value in g.outputs:
        return None
    return cs[0] if len(cs) == 1 else None


def fuse_compute_aware(g: Graph) -> Graph:
    """Pass 1: align collectives with the adjacent GEMM's memory semantics."""
    nodes = list(g.nodes)
    changed = True
    while changed:
        changed = False
        for n in nodes:
            if n.op == "allgather":
                c = _single_consumer(g, n.name)
                if c is not None and c.op == "gemm_col":
                    fused = Node(c.name, "ag_gemm", n.inputs, c.weights)
                    nodes = [x for x in nodes if x.name not in (n.name, c.name)]
                    nodes.append(fused)
                    g = Graph(_topo(nodes, g.outputs), g.outputs)
                    nodes = list(g.nodes)
                    changed = True
                    break
            if n.op == "gemm_row":
                c = _single_consumer(g, n.name)
                if c is not None and c.op in ("reduce_scatter", "allreduce"):
                    op = "gemm_rs" if c.op == "reduce_scatter" else "gemm_ar"
                    fused = Node(c.name, op, n.inputs, n.weights)
                    nodes = [x for x in nodes if x.name not in (n.name, c.name)]
                    nodes.append(fused)
                    g = Graph(_topo(nodes, g.outputs), g.outputs)
                    nodes = list(g.nodes)
                    changed = True
                    break
    return Graph(_topo(nodes, g.outputs), g.outputs)


def fuse_shared_gather(g: Graph) -> Graph:
    """Pass 1b: an ``allgather`` consumed by *several* ``gemm_col`` nodes
    (fused QKV, gate+up) becomes one ``ag_gemm_multi``: the activation
    circulates the ring once and every weight consumes each arriving chunk
    (the multi-weight pull alignment the hand-fused sub-layers used)."""
    nodes = list(g.nodes)
    for n in nodes:
        if n.op != "allgather" or n.name in g.outputs:
            continue
        cs = g.consumers(n.name)
        if len(cs) < 2 or any(c.op != "gemm_col" for c in cs):
            continue
        fused = Node("+".join(c.name for c in cs), "ag_gemm_multi",
                     n.inputs,
                     tuple(w for c in cs for w in c.weights),
                     outputs=tuple(c.name for c in cs))
        drop = {n.name} | {c.name for c in cs}
        nodes = [x for x in nodes if x.name not in drop] + [fused]
        return fuse_shared_gather(Graph(_topo(nodes, g.outputs), g.outputs))
    return g


def fuse_sublayer_chain(g: Graph) -> Graph:
    """Pass 2: gemm_rs → [add residual] → layernorm → ag_gemm ⇒ one pipeline."""
    nodes = list(g.nodes)
    for rs in list(nodes):
        if rs.op != "gemm_rs":
            continue
        # rs's value may escape as a graph output — the fused op re-exposes it
        nxt = _single_consumer(g, rs.name, allow_output=True)
        residual = None
        add_node = None
        if nxt is not None and nxt.op == "add":
            other = [v for v in nxt.inputs if v != rs.name]
            residual = other[0] if other else None
            add_node = nxt
            nxt = _single_consumer(g, nxt.name, allow_output=True)
        if nxt is None or nxt.op != "layernorm":
            continue
        ln = nxt
        ag = _single_consumer(g, ln.name)
        if ag is None or ag.op != "ag_gemm":
            continue
        ins = rs.inputs + ((residual,) if residual else ())
        fused = Node(ag.name, "fused_rs_ln_ag", ins,
                     rs.weights + ln.weights + ag.weights,
                     outputs=(ag.name, (add_node or rs).name))
        drop = {rs.name, ln.name, ag.name} | ({add_node.name} if add_node else set())
        nodes = [x for x in nodes if x.name not in drop] + [fused]
        return fuse_sublayer_chain(Graph(_topo(nodes, g.outputs), g.outputs))
    return Graph(_topo(nodes, g.outputs), g.outputs)


def pair_asymmetric(g: Graph) -> Graph:
    """Pass 3: co-schedule an independent gemm_rs + ag_gemm pair so their
    complementary ring directions share the links each step."""
    nodes = list(g.nodes)
    for a in nodes:
        if a.op != "gemm_rs":
            continue
        for b in nodes:
            if b.op != "ag_gemm" or b.name == a.name:
                continue
            if g.reaches(a.name, b.name) or g.reaches(b.name, a.name):
                continue
            fused = Node(f"{a.name}+{b.name}", "overlap_asym",
                         a.inputs + b.inputs, a.weights + b.weights,
                         outputs=(a.name, b.name))
            nodes = [x for x in nodes if x.name not in (a.name, b.name)]
            nodes.append(fused)
            return pair_asymmetric(Graph(_topo(nodes, g.outputs), g.outputs))
    return Graph(_topo(nodes, g.outputs), g.outputs)


def optimize(g: Graph, asymmetric: bool = True) -> Graph:
    g = fuse_compute_aware(g)
    g = fuse_shared_gather(g)
    g = fuse_sublayer_chain(g)
    if asymmetric:
        g = pair_asymmetric(g)
    return g


def _topo(nodes: List[Node], outputs) -> List[Node]:
    """Stable topological order by value availability."""
    avail = set()
    for n in nodes:
        if n.op == "input":
            avail |= set(n.outputs)
    ordered, pending = [], [n for n in nodes if n.op != "input"]
    ordered = [n for n in nodes if n.op == "input"]
    guard = 0
    while pending:
        guard += 1
        assert guard < 10_000, "cycle in dataflow graph"
        for n in list(pending):
            if all(v in avail for v in n.inputs):
                ordered.append(n)
                avail |= set(n.outputs)
                pending.remove(n)
    return ordered


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


def execute(g: Graph, values: Dict[str, jnp.ndarray],
            weights: Dict[str, jnp.ndarray], axis: Optional[str] = None,
            cais: CAISConfig = CAISConfig(), norm: str = "rmsnorm",
            backend=None):
    """Evaluate the graph. With ``axis`` set this must run inside shard_map
    (values/weights are local shards per the layout conventions) and every
    fused collective op dispatches through ``backend`` — a
    :class:`repro.core.backends.CollectiveBackend` instance or registry name
    (default ``"cais"``). Without ``axis``, collectives degenerate to
    identity/plain math (single-device reference)."""
    from repro.core.backends import get_backend
    from repro.models.layers import apply_norm

    env = dict(values)
    dist = axis is not None
    be = get_backend(backend if backend is not None else "cais")

    for n in g.nodes:
        if n.op == "input":
            continue
        ins = [env[v] for v in n.inputs]
        ws = [weights[k] for k in n.weights]
        if n.op == "gemm_col" or n.op == "gemm_row":
            env[n.name] = ins[0] @ ws[0]
        elif n.op == "allgather":
            env[n.name] = (jax.lax.all_gather(ins[0], axis, axis=1, tiled=True)
                           if dist else ins[0])
        elif n.op == "reduce_scatter":
            env[n.name] = (jax.lax.psum_scatter(ins[0], axis,
                                                scatter_dimension=1, tiled=True)
                           if dist else ins[0])
        elif n.op == "allreduce":
            env[n.name] = jax.lax.psum(ins[0], axis) if dist else ins[0]
        elif n.op == "layernorm":
            env[n.name] = apply_norm(norm, {"scale": ws[0]}, ins[0])
        elif n.op == "add":
            env[n.name] = ins[0] + ins[1]
        elif n.op == "custom":
            env[n.name] = n.fn(*ins)
        elif n.op == "ag_gemm":
            env[n.name] = (be.ag_gemm(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "ag_gemm_multi":
            outs = (be.ag_gemm_multi(ins[0], tuple(ws), axis, cais)
                    if dist else tuple(ins[0] @ w for w in ws))
            for name, val in zip(n.outputs, outs):
                env[name] = val
        elif n.op == "gemm_rs":
            env[n.name] = (be.gemm_rs(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "gemm_ar":
            env[n.name] = (be.gemm_ar(ins[0], ws[0], axis, cais)
                           if dist else ins[0] @ ws[0])
        elif n.op == "fused_rs_ln_ag":
            w1, scale, w2 = ws
            res = env[n.inputs[1]] if len(n.inputs) > 1 else None
            if dist:
                out, z = be.fused_rs_ln_ag(ins[0], w1, scale, w2, axis,
                                           cais, norm=norm, residual=res)
            else:
                z = ins[0] @ w1
                if res is not None:
                    z = z + res
                out = apply_norm(norm, {"scale": scale}, z) @ w2
            env[n.outputs[0]], env[n.outputs[1]] = out, z
        elif n.op == "overlap_asym":
            w_rs, w_ag = ws
            if dist:
                rs_out, ag_out = be.overlap_asymmetric(
                    (ins[0], w_rs), (ins[1], w_ag), axis, cais)
            else:
                rs_out, ag_out = ins[0] @ w_rs, ins[1] @ w_ag
            env[n.outputs[0]], env[n.outputs[1]] = rs_out, ag_out
        else:
            raise ValueError(n.op)
    return tuple(env[o] for o in g.outputs)


# ---------------------------------------------------------------------------
# Canonical sub-layer graphs (paper Fig. 12, L1–L4)
# ---------------------------------------------------------------------------


def sublayer_graph() -> Graph:
    """[GEMM (row) → RS] → LN → [AG → GEMM (col)] — the L1–L4 shape:
    e.g. L2 = second FFN layer → LayerNorm → input projection."""
    return Graph(
        nodes=[
            Node("x", "input"),
            Node("g1", "gemm_row", ("x",), ("w1",)),
            Node("rs", "reduce_scatter", ("g1",)),
            Node("ln", "layernorm", ("rs",), ("scale",)),
            Node("ag", "allgather", ("ln",)),
            Node("g2", "gemm_col", ("ag",), ("w2",)),
        ],
        outputs=("g2",),
    )


def dual_sublayer_graph() -> Graph:
    """Two independent sub-chains (e.g. two microbatches / fwd+bwd): the
    optimizer pairs the RS of one with the AG-GEMM of the other."""
    return Graph(
        nodes=[
            Node("xa", "input"),
            Node("xb", "input"),
            Node("ga", "gemm_row", ("xa",), ("wa",)),
            Node("rsa", "reduce_scatter", ("ga",)),
            Node("agb", "allgather", ("xb",)),
            Node("gb", "gemm_col", ("agb",), ("wb",)),
        ],
        outputs=("rsa", "gb"),
    )
