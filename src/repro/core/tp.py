"""Tensor-parallel block execution (pjit-callable wrappers).

Every explicit-TP sub-layer dispatches through a
:class:`repro.core.backends.CollectiveBackend` — ``barrier`` (monolithic
NVLS-style collectives), ``cais`` (the paper's decomposed collective-fused
schedules), or any backend registered by the caller. ``auto`` is the
XLA-scheduled baseline: it reports ``explicit = False`` and the model path
skips ``shard_map`` entirely (plain jnp + sharding constraints).

The dense sub-layers are *IR-driven*: ``sp_ffn`` / ``sp_attention`` build a
:mod:`repro.core.dataflow` graph of primitive ops (LN, allgather, gemm_col,
gemm_row, reduce_scatter, local custom math), run the graph-level optimizer
(paper §III-C: compute-aware alignment, shared-gather multi fusion, deep
chain fusion, asymmetric pairing), and ``execute()`` the optimized graph
inside ``shard_map`` — so new fusion rules land in the transformer without
touching the sub-layers. The unit of execution is the sub-layer chain the
paper evaluates (L1–L4): [attention out-GEMM →RS] + LN + [AG→ FFN GEMMs].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.core import dataflow as df
from repro.core.backends import CollectiveBackend, get_backend
from repro.core.primitives import CAISConfig

BATCH = sharding.BATCH_AXES
MODEL = sharding.MODEL_AXIS


@dataclass(frozen=True)
class TPContext:
    """Mesh + collective backend + chunking config for explicit TP.

    ``backend`` may be given as a registry name (``"barrier"``, ``"cais"``,
    …) or a :class:`CollectiveBackend` instance; it is resolved to an
    instance at construction."""

    mesh: Mesh
    backend: Union[str, CollectiveBackend] = "cais"
    cais: CAISConfig = CAISConfig()

    def __post_init__(self):
        object.__setattr__(self, "backend", get_backend(self.backend))

    @property
    def mode(self) -> str:
        return self.backend.name

    @property
    def tp(self) -> int:
        return sharding.axis_size(self.mesh, MODEL)


def _specs(mesh, *entries):
    return sharding._filter_spec(mesh, P(*entries))


def _smap(tpc: TPContext, fn, in_specs, out_specs):
    return sharding.shard_map(
        fn, mesh=tpc.mesh,
        in_specs=tuple(_specs(tpc.mesh, *s) for s in in_specs),
        out_specs=(tuple(_specs(tpc.mesh, *s) for s in out_specs)
                   if isinstance(out_specs, list)
                   else _specs(tpc.mesh, *out_specs)),
        check_vma=False)


# ---------------------------------------------------------------------------
# Sub-layer dataflow graphs (lowered via dataflow.optimize + execute)
# ---------------------------------------------------------------------------


def _ffn_chain_nodes(src: str, out: str, has_gate: bool, act: str,
                     tag: str = "") -> list:
    """AG → GEMM(up[, gate]) → act[(·)] → GEMM(down) → RS nodes from value
    ``src`` to value ``out`` (weight keys w_up/w_gate/w_down); ``tag``
    uniquifies node names when the chain is embedded in a larger graph."""
    from repro.models.layers import activation

    ag, up, gate, h, down = (f"agx{tag}", f"up{tag}", f"gate{tag}",
                             f"h{tag}", f"down{tag}")
    nodes = [
        df.Node(ag, "allgather", (src,)),
        df.Node(up, "gemm_col", (ag,), ("w_up",)),
    ]
    if has_gate:
        nodes.append(df.Node(gate, "gemm_col", (ag,), ("w_gate",)))
        nodes.append(df.Node(h, "custom", (up, gate),
                             fn=lambda u, g: activation(act, g) * u))
    else:
        nodes.append(df.Node(h, "custom", (up,),
                             fn=lambda u: activation(act, u)))
    nodes += [
        df.Node(down, "gemm_row", (h,), ("w_down",)),
        df.Node(out, "reduce_scatter", (down,)),
    ]
    return nodes


def ffn_sublayer_graph(has_gate: bool, act: str) -> df.Graph:
    """LN → AG → GEMM(up[, gate]) → act[(·)] → GEMM(down) → RS as IR nodes.
    ``optimize()`` turns the collectives into the backend's fused schedules
    (ag_gemm / ag_gemm_multi / gemm_rs)."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
    ] + _ffn_chain_nodes("ln", "out", has_gate, act)
    return df.Graph(nodes, outputs=("out",))


def attention_sublayer_graph(core_fn: Callable) -> df.Graph:
    """LN → AG → GEMM(q|k|v) → attention core → GEMM(out) → RS as IR nodes.
    ``core_fn(q, k, v)`` is the local attention math (rope, KV slicing,
    flash core, head reshape) — a ``custom`` node the optimizer schedules
    collectives around."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
        df.Node("agx", "allgather", ("ln",)),
        df.Node("q", "gemm_col", ("agx",), ("wq",)),
        df.Node("k", "gemm_col", ("agx",), ("wk",)),
        df.Node("v", "gemm_col", ("agx",), ("wv",)),
        df.Node("o", "custom", ("q", "k", "v"), fn=core_fn),
        df.Node("proj", "gemm_row", ("o",), ("wo",)),
        df.Node("out", "reduce_scatter", ("proj",)),
    ]
    return df.Graph(nodes, outputs=("out",))


# ---------------------------------------------------------------------------
# Whole-block dataflow graphs: attention residual → FFN/MoE residual in ONE
# graph, so pass 2 fuses the rs→ln→ag seam between the sub-layers and pass 3
# can co-schedule collectives across independent chains (microbatches).
# ---------------------------------------------------------------------------


def _attention_block_nodes(core_fn: Callable) -> list:
    """x → LN1 → AG → QKV → core → out-GEMM → RS → +x residual (value r1)."""
    return [
        df.Node("x", "input"),
        df.Node("ln1", "layernorm", ("x",), ("scale1",)),
        df.Node("agx1", "allgather", ("ln1",)),
        df.Node("q", "gemm_col", ("agx1",), ("wq",)),
        df.Node("k", "gemm_col", ("agx1",), ("wk",)),
        df.Node("v", "gemm_col", ("agx1",), ("wv",)),
        df.Node("o", "custom", ("q", "k", "v"), fn=core_fn),
        df.Node("proj", "gemm_row", ("o",), ("wo",)),
        df.Node("rs1", "reduce_scatter", ("proj",)),
        df.Node("r1", "residual", ("rs1", "x")),
    ]


def dense_block_graph(core_fn: Callable, has_gate: bool, act: str) -> df.Graph:
    """One Graph for a whole dense transformer block. After ``optimize()``
    the attention-out RS, the residual add, LN2, and the FFN input gather
    collapse into one ``fused_rs_ln_ag[_multi]`` pipeline (pass 2) — the
    cross-sub-layer seam a per-sub-layer graph can never see."""
    nodes = _attention_block_nodes(core_fn) + [
        df.Node("ln2", "layernorm", ("r1",), ("scale2",)),
    ] + _ffn_chain_nodes("ln2", "rs2", has_gate, act, tag="2") + [
        df.Node("r2", "residual", ("rs2", "r1")),
    ]
    return df.Graph(nodes, outputs=("r2",))


def moe_block_graph(core_fn: Callable, route_fn: Callable,
                    expert_fn: Callable, unroute_fn: Callable,
                    expert_weights: tuple, has_gate: bool,
                    dense_fn: Optional[Callable] = None,
                    dense_weights: tuple = ()) -> df.Graph:
    """One Graph for a whole MoE transformer block: the expert path runs as
    ``route → a2a_ffn → unroute`` IR nodes, with ``a2a_ffn`` dispatched
    through ``CollectiveBackend.a2a_expert_ffn``. ``dense_fn`` adds the
    Arctic-style parallel dense-residual MLP as a ``custom`` node."""
    nodes = _attention_block_nodes(core_fn) + [
        df.Node("ln2", "layernorm", ("r1",), ("scale2",)),
        df.Node("moe_route", "route", ("ln2",), ("router",),
                outputs=("send", "combine", "aux"), fn=route_fn),
        df.Node("eout", "a2a_ffn", ("send",), expert_weights, fn=expert_fn),
        df.Node("y", "unroute", ("eout", "combine", "ln2"), fn=unroute_fn),
    ]
    top = "y"
    if dense_fn is not None:
        nodes.append(df.Node("dmlp", "custom", ("ln2",), dense_weights,
                             fn=dense_fn))
        nodes.append(df.Node("ymoe", "add", ("y", "dmlp")))
        top = "ymoe"
    nodes.append(df.Node("r2", "residual", (top, "r1")))
    return df.Graph(nodes, outputs=("r2", "aux"))


# ---------------------------------------------------------------------------
# FFN sub-layer: LN -> AG-GEMM(up[,gate]) -> act -> GEMM-RS(down)
# ---------------------------------------------------------------------------


def sp_ffn(tpc: TPContext, x, norm_scale, w_up, w_gate, w_down,
           act: str, norm_kind: str = "rmsnorm"):
    """x: (B, S, d) logically sequence-sharded. Returns FFN(LN(x)) with the
    residual handled by the caller. ``w_gate`` may be None."""
    has_gate = w_gate is not None
    graph = df.optimize(ffn_sublayer_graph(has_gate, act))
    wnames = ("scale", "w_up") + (("w_gate",) if has_gate else ()) + \
        ("w_down",)

    def local(x, *ws):
        return df.execute(graph, {"x": x}, dict(zip(wnames, ws)),
                          axis=MODEL, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    in_specs = [(BATCH, MODEL, None),            # x sequence-sharded
                (None,),                         # norm scale replicated
                (None, MODEL)]                   # up col-sharded
    if has_gate:
        in_specs.append((None, MODEL))           # gate col-sharded
    in_specs.append((MODEL, None))               # down row-sharded
    args = (x, norm_scale, w_up) + ((w_gate,) if has_gate else ()) + \
        (w_down,)
    return _smap(tpc, local, in_specs, (BATCH, MODEL, None))(*args)


# ---------------------------------------------------------------------------
# Attention sub-layer: LN -> AG-GEMM(QKV) -> attn core -> GEMM-RS(out)
# ---------------------------------------------------------------------------


def _attention_core_fn(cfg, tp: int, window: int = 0, prefix_len: int = 0
                       ) -> Callable:
    """The local attention math (rope, KV head slicing, flash core, head
    reshape) as a closure for a ``custom`` IR node — shared by
    :func:`sp_attention` and :func:`sp_block`."""
    from repro.models.attention import attention_core
    from repro.models.layers import apply_rope

    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kv_sharded = Hkv % tp == 0

    def core(q, k, v):
        B_, S = q.shape[0], q.shape[1]
        H_loc = max(H // tp, 1)
        Hkv_loc = max(Hkv // tp, 1) if kv_sharded else Hkv
        pos = jnp.broadcast_to(jnp.arange(S), (B_, S))
        q = apply_rope(q.reshape(B_, S, H_loc, dh), pos, cfg.rope_theta)
        k = apply_rope(k.reshape(B_, S, Hkv_loc, dh), pos, cfg.rope_theta)
        v = v.reshape(B_, S, Hkv_loc, dh)
        if not kv_sharded:
            # replicated KV: slice the kv heads this device's q heads use
            # (contiguous because head sharding is contiguous)
            g = H // Hkv                    # q heads per kv head
            need = max(H_loc // g, 1)
            start = (jax.lax.axis_index(MODEL) * H_loc) // g
            k = jax.lax.dynamic_slice_in_dim(k, start, need, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, start, need, axis=2)
        o = attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                           causal=True, window=window, prefix_len=prefix_len)
        return o.reshape(B_, S, H_loc * dh)

    return core


def sp_attention(tpc: TPContext, x, norm_scale, wq, wk, wv, wo, cfg,
                 window: int = 0, prefix_len: int = 0,
                 norm_kind: str = "rmsnorm"):
    """Full Megatron-SP attention block over the collective backend.
    x: (B, S, d) sequence-sharded; Q heads shard over `model`. When
    num_kv_heads < tp (GQA/MQA), K/V weights replicate and every device
    computes the full K/V from the same gathered activation chunks — the
    standard Megatron KV-replication, and the gather is still shared with
    the Q projection (one ring circulation feeds all three)."""
    tp = tpc.tp
    kv_sharded = cfg.num_kv_heads % tp == 0
    core = _attention_core_fn(cfg, tp, window=window, prefix_len=prefix_len)

    graph = df.optimize(attention_sublayer_graph(core))

    def local(x, norm_scale, wq, wk, wv, wo):
        return df.execute(graph, {"x": x},
                          {"scale": norm_scale, "wq": wq, "wk": wk,
                           "wv": wv, "wo": wo},
                          axis=MODEL, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    kv_spec = (None, MODEL) if kv_sharded else (None, None)
    return _smap(
        tpc, local,
        in_specs=[(BATCH, MODEL, None), (None,),
                  (None, MODEL), kv_spec, kv_spec,
                  (MODEL, None)],
        out_specs=(BATCH, MODEL, None))(x, norm_scale, wq, wk, wv, wo)


# ---------------------------------------------------------------------------
# MoE FFN sub-layer over EP: backend-dispatched expert all-to-all
# ---------------------------------------------------------------------------


def sp_moe_ffn(tpc: TPContext, x, norm_scale, params, cfg,
               norm_kind: str = "rmsnorm"):
    """MoE FFN with the backend's expert-a2a pipeline (beyond-paper
    extension, EXPERIMENTS.md §Perf cell 2): each device routes its sequence
    shard's tokens to expert owners; the ``cais`` backend overlaps the
    interleaved ±direction dispatch/combine permutes with the expert GEMMs.

    Owner mapping: device j owns experts [j·E_loc, (j+1)·E_loc) when
    E ≥ tp (E % tp == 0); when E < tp (tp % E == 0) expert e lives on
    device e·(tp/E) and the others idle through the FFN (their buffers are
    zero-capacity padding). x: (B, S, d) sequence-sharded. Returns FFN(LN(x))
    (residual handled by the caller) and the load-balancing aux loss.

    The routing/expert/combine math is shared with the whole-block IR path
    (:func:`sp_block`) via the :func:`_moe_graph_fns` closures."""
    from repro.models.layers import apply_norm

    m = cfg.moe
    E = m.num_experts
    tp = tpc.tp
    cais = tpc.cais
    has_gate = "w_gate" in params
    route_fn, expert_fn, unroute_fn = _moe_graph_fns(cfg, tp, has_gate)

    def local(x, ns, router, wu, wg, wd):
        xn = apply_norm(norm_kind, {"scale": ns}, x)
        send, combine, aux = route_fn(xn, router)
        ws = (wu, wg, wd) if has_gate else (wu, wd)
        ret = tpc.backend.a2a_expert_ffn(
            send, lambda chunk: expert_fn(chunk, *ws), MODEL, cais)
        out = unroute_fn(ret, combine, xn)
        if m.dense_residual_d_ff:
            from repro.models.ffn import mlp_forward
            out = out + mlp_forward(params["dense"], xn, cfg.act)
        return out, aux

    dtype = x.dtype
    wu = params["w_up"].astype(dtype)
    wg = params["w_gate"].astype(dtype) if has_gate else \
        jnp.zeros_like(params["w_up"], dtype)
    wd = params["w_down"].astype(dtype)
    e_spec = (MODEL, None, None) if E % tp == 0 else (None, None, None)
    out, aux = _smap(
        tpc, local,
        in_specs=[(BATCH, MODEL, None), (None,), (None, None),
                  e_spec, e_spec, e_spec],
        out_specs=[(BATCH, MODEL, None), (MODEL,)])(
            x, norm_scale, params["router"], wu, wg, wd)
    return out, jnp.mean(aux)


# ---------------------------------------------------------------------------
# Whole-block execution: ONE dataflow graph per transformer block
# ---------------------------------------------------------------------------


def _moe_graph_fns(cfg, tp: int, has_gate: bool):
    """Closures for the MoE expert path (route / a2a expert compute /
    unroute) — the single home of this math, used both as IR node ``fn``s
    by :func:`sp_block`'s graph and composed directly by
    :func:`sp_moe_ffn`. Owner mapping as documented on ``sp_moe_ffn``:
    device j owns experts [j·E_loc, (j+1)·E_loc) when E ≥ tp; when E < tp
    expert e lives on device e·(tp/E) (replicated weights sliced per owner,
    zero-capacity padding elsewhere)."""
    from repro.models.ffn import _top2_dispatch
    from repro.models.layers import activation

    m = cfg.moe
    E = m.num_experts
    E_loc = max(E // tp, 1)

    def route_fn(xn, router):
        B, S_loc, d = xn.shape
        t = xn.reshape(B * S_loc, d)
        T = t.shape[0]
        logits = t.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, -1)
        cap = max(1, int(T * m.top_k / E * m.capacity_factor))
        dispatch, combine, aux = _top2_dispatch(probs[None], cap)
        dispatch, combine = dispatch[0], combine[0]     # (T, E, cap)
        # send[j]: (E_loc·cap, d) tokens for the experts device j owns
        de = jnp.einsum("tec,td->ecd", dispatch.astype(t.dtype), t)
        if E >= tp:
            send = de.reshape(tp, E_loc * cap, d)
        else:
            # owner(e) = e·(tp/E); other devices get zero-capacity padding
            stride = tp // E
            send = jnp.zeros((tp, cap, d), t.dtype)
            send = send.at[::stride].set(de)
        return send, combine, aux.astype(jnp.float32)[None]

    def expert_fn(chunk, wu, *rest):
        # chunk: (E_loc·cap, d) → per-local-expert gated FFN
        wg = rest[0] if has_gate else None
        wd = rest[-1]
        if E < tp:
            # replicated weights: slice this owner's single expert
            eidx = jax.lax.axis_index(MODEL) // (tp // E)
            wu = jax.lax.dynamic_index_in_dim(wu, eidx, 0, keepdims=True)
            wd = jax.lax.dynamic_index_in_dim(wd, eidx, 0, keepdims=True)
            if has_gate:
                wg = jax.lax.dynamic_index_in_dim(wg, eidx, 0, keepdims=True)
        c = chunk.reshape(E_loc, -1, chunk.shape[-1])
        h = jnp.einsum("ecd,edf->ecf", c, wu)
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", c, wg)
            h = activation(cfg.act, g) * h
        else:
            h = activation(cfg.act, h)
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        return out.reshape(chunk.shape)

    def unroute_fn(ret, combine, xn):
        B, S_loc, d = xn.shape
        cap = combine.shape[-1]
        if E >= tp:
            eout = ret.reshape(E, cap, d)
        else:
            eout = ret[::tp // E]
        y = jnp.einsum("tec,ecd->td", combine.astype(ret.dtype), eout)
        return y.reshape(B, S_loc, d)

    return route_fn, expert_fn, unroute_fn


def sp_block(tpc: TPContext, x, params, cfg, kind: str = "attn",
             prefix_len: int = 0, norm_kind: str = "rmsnorm"):
    """A whole pre-norm transformer block — attention residual → FFN/MoE
    residual — built as ONE dataflow graph, optimized, and executed in ONE
    ``shard_map``. Unlike the per-sub-layer path (``sp_attention`` +
    ``sp_ffn``/``sp_moe_ffn``), the graph spans the attention-out → FFN-in
    seam, so pass 2 fuses RS → residual → LN → AG into one pipeline on every
    dense block and MoE routing flows through the same IR.

    ``params`` is the block param dict from ``models.transformer.init_block``
    (``norm1``/``mixer``/``norm2``/``ffn``). x: (B, S, d) sequence-sharded.
    Returns (block output, aux loss)."""
    dtype = x.dtype
    tp = tpc.tp
    m = params["mixer"]
    kv_sharded = cfg.num_kv_heads % tp == 0
    window = cfg.window if kind == "swa" else 0
    core = _attention_core_fn(cfg, tp, window=window, prefix_len=prefix_len)

    kv_spec = (None, MODEL) if kv_sharded else (None, None)
    weights = {
        "scale1": params["norm1"]["scale"].astype(dtype),
        "wq": m["wq"].astype(dtype), "wk": m["wk"].astype(dtype),
        "wv": m["wv"].astype(dtype), "wo": m["wo"].astype(dtype),
        "scale2": params["norm2"]["scale"].astype(dtype),
    }
    specs = {
        "scale1": (None,), "wq": (None, MODEL), "wk": kv_spec,
        "wv": kv_spec, "wo": (MODEL, None), "scale2": (None,),
    }

    f = params["ffn"]
    has_gate = "w_gate" in f
    moe = cfg.moe is not None
    if moe:
        assert cfg.moe.num_experts % tp == 0, \
            "sp_block MoE path requires E % tp == 0 (see tp_applicable)"
        route_fn, expert_fn, unroute_fn = _moe_graph_fns(cfg, tp, has_gate)
        weights["router"] = f["router"]                 # stays float32
        specs["router"] = (None, None)
        e_keys = ("w_up",) + (("w_gate",) if has_gate else ()) + ("w_down",)
        for kkey in e_keys:
            weights[kkey] = f[kkey].astype(dtype)
            specs[kkey] = (MODEL, None, None)
        dense_fn, d_keys = None, ()
        if cfg.moe.dense_residual_d_ff:
            dm = f["dense"]
            dense_gate = "w_gate" in dm
            d_keys = ("d_up",) + (("d_gate",) if dense_gate else ()) + \
                ("d_down",)
            weights["d_up"] = dm["w_up"].astype(dtype)
            if dense_gate:
                weights["d_gate"] = dm["w_gate"].astype(dtype)
            weights["d_down"] = dm["w_down"].astype(dtype)
            for kkey in d_keys:
                specs[kkey] = (None, None)
            from repro.models.layers import activation

            def dense_fn(xn, du, *drest):
                dg = drest[0] if dense_gate else None
                dd = drest[-1]
                h = xn @ du
                if dense_gate:
                    h = activation(cfg.act, xn @ dg) * h
                else:
                    h = activation(cfg.act, h)
                return h @ dd

        graph = moe_block_graph(core, route_fn, expert_fn, unroute_fn,
                                e_keys, has_gate, dense_fn=dense_fn,
                                dense_weights=d_keys)
    else:
        graph = dense_block_graph(core, has_gate, cfg.act)
        weights["w_up"] = f["w_up"].astype(dtype)
        specs["w_up"] = (None, MODEL)
        if has_gate:
            weights["w_gate"] = f["w_gate"].astype(dtype)
            specs["w_gate"] = (None, MODEL)
        weights["w_down"] = f["w_down"].astype(dtype)
        specs["w_down"] = (MODEL, None)

    graph = df.optimize(graph)
    names = list(weights)

    def local(x, *ws):
        outs = df.execute(graph, {"x": x}, dict(zip(names, ws)),
                          axis=MODEL, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)
        return outs if moe else outs[0]

    in_specs = [(BATCH, MODEL, None)] + [specs[k] for k in names]
    out_specs = ([(BATCH, MODEL, None), (MODEL,)] if moe
                 else (BATCH, MODEL, None))
    res = _smap(tpc, local, in_specs, out_specs)(x, *weights.values())
    if moe:
        return res[0], jnp.mean(res[1])
    return res, jnp.float32(0.0)


def tp_applicable(cfg, kind: str, tp: int) -> bool:
    """Explicit-backend shard_map path requires Q-head and feature
    divisibility (KV heads may replicate); otherwise the block stays on the
    `auto` path (DESIGN.md §5)."""
    if kind in ("attn", "swa"):
        return cfg.num_heads % tp == 0 and cfg.norm == "rmsnorm"
    if kind == "ffn":
        return cfg.moe is None and cfg.d_ff > 0 and cfg.d_ff % tp == 0 \
            and cfg.norm == "rmsnorm"
    if kind == "moe":
        # integrated path requires true EP: with E < tp the owner mapping
        # works (primitive-level tests) but replicated expert weights turn
        # their gradients into a full-size all-reduce — measured regression,
        # EXPERIMENTS.md §Perf cell 2. Grouped-EP weight sharding is the
        # production fix (backlog); until then those archs keep `auto`.
        return cfg.moe is not None and cfg.norm == "rmsnorm" and \
            cfg.moe.num_experts % tp == 0
    return False
