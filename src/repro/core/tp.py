"""Tensor-parallel block execution (pjit-callable wrappers).

Every explicit-TP sub-layer dispatches through a
:class:`repro.core.backends.CollectiveBackend` — ``barrier`` (monolithic
NVLS-style collectives), ``cais`` (the paper's decomposed collective-fused
schedules), or any backend registered by the caller. ``auto`` is the
XLA-scheduled baseline: it reports ``explicit = False`` and the model path
skips ``shard_map`` entirely (plain jnp + sharding constraints).

The dense sub-layers are *IR-driven*: ``sp_ffn`` / ``sp_attention`` build a
:mod:`repro.core.dataflow` graph of primitive ops (LN, allgather, gemm_col,
gemm_row, reduce_scatter, local custom math), run the graph-level optimizer
(paper §III-C: compute-aware alignment, shared-gather multi fusion, deep
chain fusion, asymmetric pairing), and ``execute()`` the optimized graph
inside ``shard_map`` — so new fusion rules land in the transformer without
touching the sub-layers. The unit of execution is the sub-layer chain the
paper evaluates (L1–L4): [attention out-GEMM →RS] + LN + [AG→ FFN GEMMs].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.core import dataflow as df
from repro.core.backends import CollectiveBackend, get_backend
from repro.core.primitives import CAISConfig

BATCH = sharding.BATCH_AXES
MODEL = sharding.MODEL_AXIS


@dataclass(frozen=True)
class TPContext:
    """Mesh + collective backend + chunking config for explicit TP.

    ``backend`` may be given as a registry name (``"barrier"``, ``"cais"``,
    …) or a :class:`CollectiveBackend` instance; it is resolved to an
    instance at construction."""

    mesh: Mesh
    backend: Union[str, CollectiveBackend] = "cais"
    cais: CAISConfig = CAISConfig()

    def __post_init__(self):
        object.__setattr__(self, "backend", get_backend(self.backend))

    @property
    def mode(self) -> str:
        return self.backend.name

    @property
    def tp(self) -> int:
        return sharding.axis_size(self.mesh, MODEL)


def _specs(mesh, *entries):
    return sharding._filter_spec(mesh, P(*entries))


def _smap(tpc: TPContext, fn, in_specs, out_specs):
    return sharding.shard_map(
        fn, mesh=tpc.mesh,
        in_specs=tuple(_specs(tpc.mesh, *s) for s in in_specs),
        out_specs=(tuple(_specs(tpc.mesh, *s) for s in out_specs)
                   if isinstance(out_specs, list)
                   else _specs(tpc.mesh, *out_specs)),
        check_vma=False)


# ---------------------------------------------------------------------------
# Sub-layer dataflow graphs (lowered via dataflow.optimize + execute)
# ---------------------------------------------------------------------------


def ffn_sublayer_graph(has_gate: bool, act: str) -> df.Graph:
    """LN → AG → GEMM(up[, gate]) → act[(·)] → GEMM(down) → RS as IR nodes.
    ``optimize()`` turns the collectives into the backend's fused schedules
    (ag_gemm / ag_gemm_multi / gemm_rs)."""
    from repro.models.layers import activation

    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
        df.Node("agx", "allgather", ("ln",)),
        df.Node("up", "gemm_col", ("agx",), ("w_up",)),
    ]
    if has_gate:
        nodes.append(df.Node("gate", "gemm_col", ("agx",), ("w_gate",)))
        nodes.append(df.Node("h", "custom", ("up", "gate"),
                             fn=lambda u, g: activation(act, g) * u))
    else:
        nodes.append(df.Node("h", "custom", ("up",),
                             fn=lambda u: activation(act, u)))
    nodes += [
        df.Node("down", "gemm_row", ("h",), ("w_down",)),
        df.Node("out", "reduce_scatter", ("down",)),
    ]
    return df.Graph(nodes, outputs=("out",))


def attention_sublayer_graph(core_fn: Callable) -> df.Graph:
    """LN → AG → GEMM(q|k|v) → attention core → GEMM(out) → RS as IR nodes.
    ``core_fn(q, k, v)`` is the local attention math (rope, KV slicing,
    flash core, head reshape) — a ``custom`` node the optimizer schedules
    collectives around."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
        df.Node("agx", "allgather", ("ln",)),
        df.Node("q", "gemm_col", ("agx",), ("wq",)),
        df.Node("k", "gemm_col", ("agx",), ("wk",)),
        df.Node("v", "gemm_col", ("agx",), ("wv",)),
        df.Node("o", "custom", ("q", "k", "v"), fn=core_fn),
        df.Node("proj", "gemm_row", ("o",), ("wo",)),
        df.Node("out", "reduce_scatter", ("proj",)),
    ]
    return df.Graph(nodes, outputs=("out",))


# ---------------------------------------------------------------------------
# FFN sub-layer: LN -> AG-GEMM(up[,gate]) -> act -> GEMM-RS(down)
# ---------------------------------------------------------------------------


def sp_ffn(tpc: TPContext, x, norm_scale, w_up, w_gate, w_down,
           act: str, norm_kind: str = "rmsnorm"):
    """x: (B, S, d) logically sequence-sharded. Returns FFN(LN(x)) with the
    residual handled by the caller. ``w_gate`` may be None."""
    has_gate = w_gate is not None
    graph = df.optimize(ffn_sublayer_graph(has_gate, act))
    wnames = ("scale", "w_up") + (("w_gate",) if has_gate else ()) + \
        ("w_down",)

    def local(x, *ws):
        return df.execute(graph, {"x": x}, dict(zip(wnames, ws)),
                          axis=MODEL, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    in_specs = [(BATCH, MODEL, None),            # x sequence-sharded
                (None,),                         # norm scale replicated
                (None, MODEL)]                   # up col-sharded
    if has_gate:
        in_specs.append((None, MODEL))           # gate col-sharded
    in_specs.append((MODEL, None))               # down row-sharded
    args = (x, norm_scale, w_up) + ((w_gate,) if has_gate else ()) + \
        (w_down,)
    return _smap(tpc, local, in_specs, (BATCH, MODEL, None))(*args)


# ---------------------------------------------------------------------------
# Attention sub-layer: LN -> AG-GEMM(QKV) -> attn core -> GEMM-RS(out)
# ---------------------------------------------------------------------------


def sp_attention(tpc: TPContext, x, norm_scale, wq, wk, wv, wo, cfg,
                 window: int = 0, prefix_len: int = 0,
                 norm_kind: str = "rmsnorm"):
    """Full Megatron-SP attention block over the collective backend.
    x: (B, S, d) sequence-sharded; Q heads shard over `model`. When
    num_kv_heads < tp (GQA/MQA), K/V weights replicate and every device
    computes the full K/V from the same gathered activation chunks — the
    standard Megatron KV-replication, and the gather is still shared with
    the Q projection (one ring circulation feeds all three)."""
    from repro.models.attention import attention_core
    from repro.models.layers import apply_rope

    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    tp = tpc.tp
    kv_sharded = Hkv % tp == 0

    def core(q, k, v):
        B_, S = q.shape[0], q.shape[1]
        H_loc = max(H // tp, 1)
        Hkv_loc = max(Hkv // tp, 1) if kv_sharded else Hkv
        pos = jnp.broadcast_to(jnp.arange(S), (B_, S))
        q = apply_rope(q.reshape(B_, S, H_loc, dh), pos, cfg.rope_theta)
        k = apply_rope(k.reshape(B_, S, Hkv_loc, dh), pos, cfg.rope_theta)
        v = v.reshape(B_, S, Hkv_loc, dh)
        if not kv_sharded:
            # replicated KV: slice the kv heads this device's q heads use
            # (contiguous because head sharding is contiguous)
            g = H // Hkv                    # q heads per kv head
            need = max(H_loc // g, 1)
            start = (jax.lax.axis_index(MODEL) * H_loc) // g
            k = jax.lax.dynamic_slice_in_dim(k, start, need, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, start, need, axis=2)
        o = attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                           causal=True, window=window, prefix_len=prefix_len)
        return o.reshape(B_, S, H_loc * dh)

    graph = df.optimize(attention_sublayer_graph(core))

    def local(x, norm_scale, wq, wk, wv, wo):
        return df.execute(graph, {"x": x},
                          {"scale": norm_scale, "wq": wq, "wk": wk,
                           "wv": wv, "wo": wo},
                          axis=MODEL, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    kv_spec = (None, MODEL) if kv_sharded else (None, None)
    return _smap(
        tpc, local,
        in_specs=[(BATCH, MODEL, None), (None,),
                  (None, MODEL), kv_spec, kv_spec,
                  (MODEL, None)],
        out_specs=(BATCH, MODEL, None))(x, norm_scale, wq, wk, wv, wo)


# ---------------------------------------------------------------------------
# MoE FFN sub-layer over EP: backend-dispatched expert all-to-all
# ---------------------------------------------------------------------------


def sp_moe_ffn(tpc: TPContext, x, norm_scale, params, cfg,
               norm_kind: str = "rmsnorm"):
    """MoE FFN with the backend's expert-a2a pipeline (beyond-paper
    extension, EXPERIMENTS.md §Perf cell 2): each device routes its sequence
    shard's tokens to expert owners; the ``cais`` backend overlaps the
    interleaved ±direction dispatch/combine permutes with the expert GEMMs.

    Owner mapping: device j owns experts [j·E_loc, (j+1)·E_loc) when
    E ≥ tp (E % tp == 0); when E < tp (tp % E == 0) expert e lives on
    device e·(tp/E) and the others idle through the FFN (their buffers are
    zero-capacity padding). x: (B, S, d) sequence-sharded. Returns FFN(LN(x))
    (residual handled by the caller) and the load-balancing aux loss."""
    from repro.models.ffn import _top2_dispatch
    from repro.models.layers import activation, apply_norm

    m = cfg.moe
    E = m.num_experts
    tp = tpc.tp
    cais = tpc.cais
    E_loc = max(E // tp, 1)
    has_gate = "w_gate" in params

    def local(x, ns, router, wu, wg, wd):
        B, S_loc, d = x.shape
        xn = apply_norm(norm_kind, {"scale": ns}, x)
        t = xn.reshape(B * S_loc, d)
        T = t.shape[0]

        logits = t.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, -1)
        cap = max(1, int(T * m.top_k / E * m.capacity_factor))
        dispatch, combine, aux = _top2_dispatch(probs[None], cap)
        dispatch, combine = dispatch[0], combine[0]     # (T, E, cap)

        # send[j]: (E_loc·cap, d) tokens for the experts device j owns
        de = jnp.einsum("tec,td->ecd", dispatch.astype(t.dtype), t)
        if E >= tp:
            send = de.reshape(tp, E_loc * cap, d)
        else:
            # owner(e) = e·(tp/E); other devices get zero-capacity padding
            stride = tp // E
            send = jnp.zeros((tp, cap, d), t.dtype)
            send = send.at[::stride].set(de)

        if E >= tp:
            wu_l, wg_l, wd_l = wu, wg, wd   # already the local expert shard
        else:
            # replicated weights: slice this owner's single expert
            eidx = jax.lax.axis_index(MODEL) // (tp // E)
            wu_l = jax.lax.dynamic_index_in_dim(wu, eidx, 0, keepdims=True)
            wg_l = jax.lax.dynamic_index_in_dim(wg, eidx, 0, keepdims=True)
            wd_l = jax.lax.dynamic_index_in_dim(wd, eidx, 0, keepdims=True)

        def expert_ffn(chunk):
            # chunk: (E_loc·cap, d) → per-local-expert gated FFN
            c = chunk.reshape(E_loc, -1, d)
            h = jnp.einsum("ecd,edf->ecf", c, wu_l)
            if has_gate:
                g = jnp.einsum("ecd,edf->ecf", c, wg_l)
                h = activation(cfg.act, g) * h
            else:
                h = activation(cfg.act, h)
            out = jnp.einsum("ecf,efd->ecd", h, wd_l)
            return out.reshape(chunk.shape)

        ret = tpc.backend.a2a_expert_ffn(send, expert_ffn, MODEL, cais)

        if E >= tp:
            eout = ret.reshape(E, cap, d)
        else:
            eout = ret[::tp // E]
        y = jnp.einsum("tec,ecd->td", combine.astype(t.dtype), eout)
        out = y.reshape(B, S_loc, d)
        if m.dense_residual_d_ff:
            from repro.models.ffn import mlp_forward
            out = out + mlp_forward(params["dense"], xn, cfg.act)
        return out, aux.astype(jnp.float32)[None]

    dtype = x.dtype
    wu = params["w_up"].astype(dtype)
    wg = params["w_gate"].astype(dtype) if has_gate else \
        jnp.zeros_like(params["w_up"], dtype)
    wd = params["w_down"].astype(dtype)
    e_spec = (MODEL, None, None) if E % tp == 0 else (None, None, None)
    out, aux = _smap(
        tpc, local,
        in_specs=[(BATCH, MODEL, None), (None,), (None, None),
                  e_spec, e_spec, e_spec],
        out_specs=[(BATCH, MODEL, None), (MODEL,)])(
            x, norm_scale, params["router"], wu, wg, wd)
    return out, jnp.mean(aux)


def tp_applicable(cfg, kind: str, tp: int) -> bool:
    """Explicit-backend shard_map path requires Q-head and feature
    divisibility (KV heads may replicate); otherwise the block stays on the
    `auto` path (DESIGN.md §5)."""
    if kind in ("attn", "swa"):
        return cfg.num_heads % tp == 0 and cfg.norm == "rmsnorm"
    if kind == "ffn":
        return cfg.moe is None and cfg.d_ff > 0 and cfg.d_ff % tp == 0 \
            and cfg.norm == "rmsnorm"
    if kind == "moe":
        # integrated path requires true EP: with E < tp the owner mapping
        # works (primitive-level tests) but replicated expert weights turn
        # their gradients into a full-size all-reduce — measured regression,
        # EXPERIMENTS.md §Perf cell 2. Grouped-EP weight sharding is the
        # production fix (backlog); until then those archs keep `auto`.
        return cfg.moe is not None and cfg.norm == "rmsnorm" and \
            cfg.moe.num_experts % tp == 0
    return False
