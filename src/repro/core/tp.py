"""Tensor-parallel block execution (pjit-callable wrappers).

Every explicit-TP sub-layer dispatches through a
:class:`repro.core.backends.CollectiveBackend` — ``barrier`` (monolithic
NVLS-style collectives), ``cais`` (the paper's decomposed collective-fused
schedules), or any backend registered by the caller. ``auto`` is the
XLA-scheduled baseline: it reports ``explicit = False`` and the model path
skips ``shard_map`` entirely (plain jnp + sharding constraints).

The dense sub-layers are *IR-driven*: ``sp_ffn`` / ``sp_attention`` build a
:mod:`repro.core.dataflow` graph of primitive ops (LN, allgather, gemm_col,
gemm_row, reduce_scatter, local custom math), run the graph-level optimizer
(paper §III-C: compute-aware alignment, shared-gather multi fusion, deep
chain fusion, asymmetric pairing), and ``execute()`` the optimized graph
inside ``shard_map`` — so new fusion rules land in the transformer without
touching the sub-layers. The unit of execution is the sub-layer chain the
paper evaluates (L1–L4): [attention out-GEMM →RS] + LN + [AG→ FFN GEMMs].

The model path executes at *period* scope (:func:`sp_period`): every block
of a ``cfg.layer_pattern`` period concatenates into ONE graph run in ONE
``shard_map``, so the optimizer also sees the block→block seams —
cross-block RS→residual→LN→AG fusion (pass 2) and deterministic asymmetric
pairing (pass 3) fire inside ``stack_forward``, not just in tests.

A straight-line period is fully serialized after pass-2 fusion, so pass 3
has nothing to pair; ``num_microbatches`` (a :class:`TPContext` knob, or a
direct ``sp_period`` argument) splits the batch into that many independent
per-microbatch graph chains merged into the SAME graph
(``merge_graphs(share_weights=True)``) and re-concatenated inside the same
single ``shard_map`` — giving pass 3 the cross-chain ``gemm_rs`` /
``ag_gemm`` pairs it needs to emit ``overlap_asym`` inside the model path.
``"auto"`` sizes the split via :func:`repro.core.coordination.
plan_microbatches`. See ``docs/architecture.md`` for the full layer map.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.core import coordination
from repro.core import dataflow as df
from repro.core.backends import CollectiveBackend, get_backend
from repro.core.primitives import CAISConfig

if TYPE_CHECKING:                                # pragma: no cover
    from repro.runtime import TPConfig

BATCH = sharding.BATCH_AXES
MODEL = sharding.MODEL_AXIS
# the data-parallel mesh axes a weight gradient must be psummed over (the
# batch is sharded across them; weights are replicated there)
_BATCH_AXES = BATCH if isinstance(BATCH, tuple) else (BATCH,)


@dataclass(frozen=True)
class TPContext:
    """Mesh + collective backend + chunking config for explicit TP.

    ``backend`` may be given as a registry name (``"barrier"``, ``"cais"``,
    …) or a :class:`CollectiveBackend` instance; it is resolved to an
    instance at construction. ``num_microbatches`` is the period-graph
    batch split (int, or ``"auto"`` to size it from the α-β model via
    :func:`repro.core.coordination.plan_microbatches`); 1 disables
    splitting. ``planner`` drives pass 3 of the graph optimizer:
    ``"greedy"`` (deterministic nearest-independent-first, the default) or
    ``"perfsim"`` (the :mod:`repro.plan` search: simulated-makespan argmin
    over pairings/chunks/microbatch splits, memoized in the plan cache).
    ``hw`` is the α-β target-hardware model the microbatch planner and the
    perfsim fabric read — injectable so tests can pin behaviour with a
    scaled-down fabric. ``graph_backward`` routes period training
    gradients — dense, MoE, and the replicated-activation decode/ragged
    layout — through the graph-built custom VJP (``docs/training.md``)
    instead of JAX autodiff of the executed forward."""

    mesh: Mesh
    backend: Union[str, CollectiveBackend] = "cais"
    cais: CAISConfig = CAISConfig()
    num_microbatches: Union[int, str] = 1
    planner: str = "greedy"
    hw: "coordination.HWSpec" = coordination.V5E
    graph_backward: bool = True

    def __post_init__(self):
        object.__setattr__(self, "backend", get_backend(self.backend))
        # thread the target-hardware model into the cais chunk planner so
        # the backend can plan per-tier chunk counts (inter-node legs plan
        # against hw.inter_tier()) without a second plumbing path
        if self.cais.hw is None:
            object.__setattr__(
                self, "cais", dataclasses.replace(self.cais, hw=self.hw))

    @classmethod
    def from_config(cls, tp: "TPConfig", mesh: Mesh,
                    hw: "coordination.HWSpec" = coordination.V5E
                    ) -> "TPContext":
        """THE construction path from the runtime-level
        :class:`repro.runtime.TPConfig` to an execution context. Every model
        entry point (``models/transformer``, ``serve/engine``, launchers)
        routes through here so a ``Runtime.tp`` knob can never silently
        diverge from what the mesh actually executes."""
        return cls(mesh=mesh, backend=tp.mode,
                   cais=CAISConfig(num_chunks=tp.chunks,
                                   bidirectional=tp.bidirectional),
                   num_microbatches=tp.microbatches, planner=tp.planner,
                   hw=hw, graph_backward=tp.graph_backward)

    @property
    def mode(self) -> str:
        return self.backend.name

    @property
    def tp(self) -> int:
        """Total TP degree (flat axis size, or tp_in·tp_out on a 2D mesh)."""
        return sharding.tp_size(self.mesh)

    @property
    def tp_axes(self):
        """The TP axis entry for specs / collectives: ``"model"`` on a flat
        mesh, the composite ``("tp_in", "tp_out")`` tuple on a 2D one."""
        return sharding.tp_axes(self.mesh)

    @property
    def is_2d(self) -> bool:
        return isinstance(self.tp_axes, tuple)

    @property
    def route_axis(self):
        """The axis the MoE expert all-to-all crosses: the slow ``tp_out``
        ring on a 2D mesh (grouped-EP — experts replicate across ``tp_in``),
        the full model axis on a flat one."""
        ax = self.tp_axes
        return ax[-1] if isinstance(ax, tuple) else ax

    @property
    def route_ring(self) -> int:
        """Ring size of :attr:`route_axis` (the expert-sharding degree)."""
        return sharding.axis_size(self.mesh, self.route_axis)

    @property
    def topology(self):
        """(n_inner, n_outer) ring sizes — (tp, 1) on a flat mesh."""
        ax = self.tp_axes
        if isinstance(ax, tuple):
            return (sharding.axis_size(self.mesh, ax[0]),
                    sharding.axis_size(self.mesh, ax[-1]))
        return (self.tp, 1)


def _specs(mesh, *entries):
    return sharding._filter_spec(mesh, P(*entries))


def _smap(tpc: TPContext, fn, in_specs, out_specs):
    return sharding.shard_map(
        fn, mesh=tpc.mesh,
        in_specs=tuple(_specs(tpc.mesh, *s) for s in in_specs),
        out_specs=(tuple(_specs(tpc.mesh, *s) for s in out_specs)
                   if isinstance(out_specs, list)
                   else _specs(tpc.mesh, *out_specs)),
        check_vma=False)


@dataclass(frozen=True)
class SPOptions:
    """Shared keyword-only options for the ``sp_*`` entry points
    (``sp_ffn`` / ``sp_attention`` / ``sp_block`` / ``sp_period``), so new
    execution knobs land in one place instead of being re-threaded through
    every signature. Pass as ``opts=SPOptions(...)``; the individual fields
    are also still accepted as direct keywords (folded into the options
    object) so existing call sites keep working.

    ``prefix_len`` marks leading prefix-LM (bidirectional) positions;
    ``window`` is the SWA window for :func:`sp_attention` (period entry
    points take it from the block kind); ``seq_sharded=False`` selects the
    decode/ragged replicated-activation allreduce schedule;
    ``num_microbatches`` overrides the :class:`TPContext` knob for one call."""

    prefix_len: int = 0
    norm_kind: str = "rmsnorm"
    seq_sharded: bool = True
    num_microbatches: Union[int, str, None] = None
    window: int = 0


def _sp_opts(opts: Optional[SPOptions], legacy: dict) -> SPOptions:
    """Fold direct-keyword options into an :class:`SPOptions`."""
    opts = opts if opts is not None else SPOptions()
    if legacy:
        bad = sorted(set(legacy) - set(SPOptions.__dataclass_fields__))
        if bad:
            raise TypeError(f"unknown sp_* option {bad[0]!r}")
        opts = dataclasses.replace(opts, **legacy)
    return opts


# ---------------------------------------------------------------------------
# Sub-layer dataflow graphs (lowered via dataflow.optimize + execute)
# ---------------------------------------------------------------------------


def _ffn_chain_nodes(src: str, out: str, has_gate: bool, act: str,
                     tag: str = "", p: str = "",
                     seq_sharded: bool = True) -> list:
    """AG → GEMM(up[, gate]) → act[(·)] → GEMM(down) → RS nodes from value
    ``src`` to value ``out`` (weight keys w_up/w_gate/w_down); ``tag``
    uniquifies node names when the chain is embedded in a larger graph and
    ``p`` namespaces node names AND weight keys (period graphs, one prefix
    per block). With ``seq_sharded=False`` (decode-style TP: the activation
    is replicated, not sequence-sharded) the gather is skipped and the chain
    ends in an allreduce instead of a reduce-scatter."""
    from repro.models.layers import activation

    ag, up, gate, h, down = (f"{p}agx{tag}", f"{p}up{tag}", f"{p}gate{tag}",
                             f"{p}h{tag}", f"{p}down{tag}")
    nodes = []
    if seq_sharded:
        nodes.append(df.Node(ag, "allgather", (src,)))
        gin = ag
    else:
        gin = src
    nodes.append(df.Node(up, "gemm_col", (gin,), (p + "w_up",)))
    if has_gate:
        nodes.append(df.Node(gate, "gemm_col", (gin,), (p + "w_gate",)))
        nodes.append(df.Node(h, "custom", (up, gate),
                             fn=lambda u, g: activation(act, g) * u))
    else:
        nodes.append(df.Node(h, "custom", (up,),
                             fn=lambda u: activation(act, u)))
    nodes += [
        df.Node(down, "gemm_row", (h,), (p + "w_down",)),
        df.Node(out, "reduce_scatter" if seq_sharded else "allreduce",
                (down,)),
    ]
    return nodes


def ffn_sublayer_graph(has_gate: bool, act: str) -> df.Graph:
    """LN → AG → GEMM(up[, gate]) → act[(·)] → GEMM(down) → RS as IR nodes.
    ``optimize()`` turns the collectives into the backend's fused schedules
    (ag_gemm / ag_gemm_multi / gemm_rs)."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
    ] + _ffn_chain_nodes("ln", "out", has_gate, act)
    return df.Graph(nodes, outputs=("out",))


def attention_sublayer_graph(core_fn: Callable) -> df.Graph:
    """LN → AG → GEMM(q|k|v) → attention core → GEMM(out) → RS as IR nodes.
    ``core_fn(q, k, v)`` is the local attention math (rope, KV slicing,
    flash core, head reshape) — a ``custom`` node the optimizer schedules
    collectives around."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ln", "layernorm", ("x",), ("scale",)),
        df.Node("agx", "allgather", ("ln",)),
        df.Node("q", "gemm_col", ("agx",), ("wq",)),
        df.Node("k", "gemm_col", ("agx",), ("wk",)),
        df.Node("v", "gemm_col", ("agx",), ("wv",)),
        df.Node("o", "custom", ("q", "k", "v"), fn=core_fn),
        df.Node("proj", "gemm_row", ("o",), ("wo",)),
        df.Node("out", "reduce_scatter", ("proj",)),
    ]
    return df.Graph(nodes, outputs=("out",))


# ---------------------------------------------------------------------------
# Block graph fragments: attention residual → FFN/MoE residual as namespaced
# node lists that chain into whole-block and whole-PERIOD graphs, so pass 2
# fuses the rs→ln→ag seams between sub-layers AND between blocks, and pass 3
# can co-schedule collectives across independent chains (microbatches).
# ---------------------------------------------------------------------------


def _attention_block_nodes(core_fn: Callable, p: str = "", src: str = "x",
                           seq_sharded: bool = True) -> list:
    """src → LN1 → [AG →] QKV → core → out-GEMM → RS|AR → +src residual
    (value ``{p}r1``). ``p`` namespaces node names and weight keys; with
    ``seq_sharded=False`` the gather is skipped (replicated activation) and
    the out-projection reduces with an allreduce."""
    nodes = [df.Node(f"{p}ln1", "layernorm", (src,), (f"{p}scale1",))]
    if seq_sharded:
        nodes.append(df.Node(f"{p}agx1", "allgather", (f"{p}ln1",)))
        gin = f"{p}agx1"
    else:
        gin = f"{p}ln1"
    nodes += [
        df.Node(f"{p}q", "gemm_col", (gin,), (f"{p}wq",)),
        df.Node(f"{p}k", "gemm_col", (gin,), (f"{p}wk",)),
        df.Node(f"{p}v", "gemm_col", (gin,), (f"{p}wv",)),
        df.Node(f"{p}o", "custom", (f"{p}q", f"{p}k", f"{p}v"), fn=core_fn),
        df.Node(f"{p}proj", "gemm_row", (f"{p}o",), (f"{p}wo",)),
        df.Node(f"{p}rs1", "reduce_scatter" if seq_sharded else "allreduce",
                (f"{p}proj",)),
        df.Node(f"{p}r1", "residual", (f"{p}rs1", src)),
    ]
    return nodes


def _dense_block_nodes(core_fn: Callable, has_gate: bool, act: str,
                       p: str = "", src: str = "x",
                       seq_sharded: bool = True):
    """One dense block as a graph fragment: returns (nodes, out_value)."""
    nodes = _attention_block_nodes(core_fn, p, src, seq_sharded) + [
        df.Node(f"{p}ln2", "layernorm", (f"{p}r1",), (f"{p}scale2",)),
    ] + _ffn_chain_nodes(f"{p}ln2", f"{p}rs2", has_gate, act, tag="2", p=p,
                         seq_sharded=seq_sharded) + [
        df.Node(f"{p}r2", "residual", (f"{p}rs2", f"{p}r1")),
    ]
    return nodes, f"{p}r2"


def _moe_block_nodes(core_fn: Callable, route_fn: Callable,
                     expert_fn: Callable, unroute_fn: Callable,
                     expert_weights: tuple,
                     dense_fn: Optional[Callable] = None,
                     dense_weights: tuple = (), p: str = "",
                     src: str = "x"):
    """One MoE block as a graph fragment: returns (nodes, out_value,
    aux_value). ``expert_weights``/``dense_weights`` are the (already
    namespaced) weight keys of the expert FFN / dense-residual MLP."""
    nodes = _attention_block_nodes(core_fn, p, src) + [
        df.Node(f"{p}ln2", "layernorm", (f"{p}r1",), (f"{p}scale2",)),
        df.Node(f"{p}moe_route", "route", (f"{p}ln2",), (f"{p}router",),
                outputs=(f"{p}send", f"{p}combine", f"{p}aux"), fn=route_fn),
        df.Node(f"{p}eout", "a2a_ffn", (f"{p}send",), expert_weights,
                fn=expert_fn),
        df.Node(f"{p}y", "unroute", (f"{p}eout", f"{p}combine", f"{p}ln2"),
                fn=unroute_fn),
    ]
    top = f"{p}y"
    if dense_fn is not None:
        nodes.append(df.Node(f"{p}dmlp", "custom", (f"{p}ln2",),
                             dense_weights, fn=dense_fn))
        nodes.append(df.Node(f"{p}ymoe", "add", (top, f"{p}dmlp")))
        top = f"{p}ymoe"
    nodes.append(df.Node(f"{p}r2", "residual", (top, f"{p}r1")))
    return nodes, f"{p}r2", f"{p}aux"


def dense_block_graph(core_fn: Callable, has_gate: bool, act: str) -> df.Graph:
    """One Graph for a whole dense transformer block. After ``optimize()``
    the attention-out RS, the residual add, LN2, and the FFN input gather
    collapse into one ``fused_rs_ln_ag[_multi]`` pipeline (pass 2) — the
    cross-sub-layer seam a per-sub-layer graph can never see."""
    nodes, out = _dense_block_nodes(core_fn, has_gate, act)
    return df.Graph([df.Node("x", "input")] + nodes, outputs=(out,))


def dense_period_graph(core_fns: Sequence[Callable], has_gate: bool,
                       act: str) -> df.Graph:
    """One Graph for a PERIOD of dense blocks (one core_fn per block),
    chained through per-block ``b{i}.`` namespaces. With ≥2 blocks the
    optimizer sees the block→block seam: block k's FFN-out RS → residual →
    block k+1's LN1 → QKV shared gather fuses into one cross-block
    ``fused_rs_ln_ag_multi`` (pass 2)."""
    nodes = [df.Node("x", "input")]
    src = "x"
    for i, core_fn in enumerate(core_fns):
        ns, src = _dense_block_nodes(core_fn, has_gate, act, p=f"b{i}.",
                                     src=src)
        nodes += ns
    return df.Graph(nodes, outputs=(src,))


def moe_block_graph(core_fn: Callable, route_fn: Callable,
                    expert_fn: Callable, unroute_fn: Callable,
                    expert_weights: tuple, has_gate: bool,
                    dense_fn: Optional[Callable] = None,
                    dense_weights: tuple = ()) -> df.Graph:
    """One Graph for a whole MoE transformer block: the expert path runs as
    ``route → a2a_ffn → unroute`` IR nodes, with ``a2a_ffn`` dispatched
    through ``CollectiveBackend.a2a_expert_ffn``. ``dense_fn`` adds the
    Arctic-style parallel dense-residual MLP as a ``custom`` node. Pass 2
    fuses the attention-out RS → residual → LN2 → router seam into
    ``fused_rs_ln`` (the trailing collective is the expert all-to-all)."""
    nodes, out, aux = _moe_block_nodes(core_fn, route_fn, expert_fn,
                                       unroute_fn, expert_weights,
                                       dense_fn, dense_weights)
    return df.Graph([df.Node("x", "input")] + nodes, outputs=(out, aux))


# ---------------------------------------------------------------------------
# FFN sub-layer: LN -> AG-GEMM(up[,gate]) -> act -> GEMM-RS(down)
# ---------------------------------------------------------------------------


def sp_ffn(tpc: TPContext, x, norm_scale, w_up, w_gate, w_down,
           act: str, *, opts: Optional[SPOptions] = None, **kw):
    """x: (B, S, d) logically sequence-sharded. Returns FFN(LN(x)) with the
    residual handled by the caller. ``w_gate`` may be None. Options (e.g.
    ``norm_kind``) via ``opts`` / :class:`SPOptions` keywords."""
    o = _sp_opts(opts, kw)
    norm_kind = o.norm_kind
    has_gate = w_gate is not None
    graph = df.optimize(ffn_sublayer_graph(has_gate, act))
    wnames = ("scale", "w_up") + (("w_gate",) if has_gate else ()) + \
        ("w_down",)

    M = tpc.tp_axes

    def local(x, *ws):
        return df.execute(graph, {"x": x}, dict(zip(wnames, ws)),
                          axis=M, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    in_specs = [(BATCH, M, None),                # x sequence-sharded
                (None,),                         # norm scale replicated
                (None, M)]                       # up col-sharded
    if has_gate:
        in_specs.append((None, M))               # gate col-sharded
    in_specs.append((M, None))                   # down row-sharded
    args = (x, norm_scale, w_up) + ((w_gate,) if has_gate else ()) + \
        (w_down,)
    return _smap(tpc, local, in_specs, (BATCH, M, None))(*args)


# ---------------------------------------------------------------------------
# Attention sub-layer: LN -> AG-GEMM(QKV) -> attn core -> GEMM-RS(out)
# ---------------------------------------------------------------------------


def _attention_core_fn(cfg, tp: int, window: int = 0, prefix_len: int = 0,
                       axis=MODEL) -> Callable:
    """The local attention math (rope, KV head slicing, flash core, head
    reshape) as a closure for a ``custom`` IR node — shared by
    :func:`sp_attention` and :func:`sp_block`. ``axis`` is the TP axis entry
    (a name, or the composite 2D tuple — the replicated-KV slice uses the
    flattened shard index, which matches contiguous head sharding)."""
    from repro.models.attention import attention_core
    from repro.models.layers import apply_rope

    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kv_sharded = Hkv % tp == 0

    def core(q, k, v):
        B_, S = q.shape[0], q.shape[1]
        H_loc = max(H // tp, 1)
        Hkv_loc = max(Hkv // tp, 1) if kv_sharded else Hkv
        pos = jnp.broadcast_to(jnp.arange(S), (B_, S))
        q = apply_rope(q.reshape(B_, S, H_loc, dh), pos, cfg.rope_theta)
        k = apply_rope(k.reshape(B_, S, Hkv_loc, dh), pos, cfg.rope_theta)
        v = v.reshape(B_, S, Hkv_loc, dh)
        if not kv_sharded:
            # replicated KV: slice the kv heads this device's q heads use
            # (contiguous because head sharding is contiguous)
            g = H // Hkv                    # q heads per kv head
            need = max(H_loc // g, 1)
            start = (sharding.shard_map_axis_index(axis) * H_loc) // g
            k = jax.lax.dynamic_slice_in_dim(k, start, need, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, start, need, axis=2)
        o = attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                           causal=True, window=window, prefix_len=prefix_len)
        return o.reshape(B_, S, H_loc * dh)

    return core


def sp_attention(tpc: TPContext, x, norm_scale, wq, wk, wv, wo, cfg, *,
                 opts: Optional[SPOptions] = None, **kw):
    """Full Megatron-SP attention block over the collective backend.
    x: (B, S, d) sequence-sharded; Q heads shard over `model`. When
    num_kv_heads < tp (GQA/MQA), K/V weights replicate and every device
    computes the full K/V from the same gathered activation chunks — the
    standard Megatron KV-replication, and the gather is still shared with
    the Q projection (one ring circulation feeds all three). Options
    (``window``, ``prefix_len``, ``norm_kind``) via ``opts`` /
    :class:`SPOptions` keywords."""
    o = _sp_opts(opts, kw)
    norm_kind = o.norm_kind
    tp = tpc.tp
    M = tpc.tp_axes
    kv_sharded = cfg.num_kv_heads % tp == 0
    core = _attention_core_fn(cfg, tp, window=o.window,
                              prefix_len=o.prefix_len, axis=M)

    graph = df.optimize(attention_sublayer_graph(core))

    def local(x, norm_scale, wq, wk, wv, wo):
        return df.execute(graph, {"x": x},
                          {"scale": norm_scale, "wq": wq, "wk": wk,
                           "wv": wv, "wo": wo},
                          axis=M, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)[0]

    kv_spec = (None, M) if kv_sharded else (None, None)
    return _smap(
        tpc, local,
        in_specs=[(BATCH, M, None), (None,),
                  (None, M), kv_spec, kv_spec,
                  (M, None)],
        out_specs=(BATCH, M, None))(x, norm_scale, wq, wk, wv, wo)


# ---------------------------------------------------------------------------
# MoE FFN sub-layer over EP: backend-dispatched expert all-to-all
# ---------------------------------------------------------------------------


def sp_moe_ffn(tpc: TPContext, x, norm_scale, params, cfg,
               norm_kind: str = "rmsnorm"):
    """MoE FFN with the backend's expert-a2a pipeline (beyond-paper
    extension, EXPERIMENTS.md §Perf cell 2): each device routes its sequence
    shard's tokens to expert owners; the ``cais`` backend overlaps the
    interleaved ±direction dispatch/combine permutes with the expert GEMMs.

    Owner mapping: rank j of the ROUTE ring owns experts
    [j·E_loc, (j+1)·E_loc) when E ≥ ring (E % ring == 0); when E < ring
    (ring % E == 0) expert e lives on rank e·(ring/E) and the others idle
    through the FFN (their buffers are zero-capacity padding). On a flat
    mesh the route ring is the whole model axis; on a hierarchical 2D mesh
    it is the slow ``tp_out`` axis only — grouped EP: expert weights shard
    over ``tp_out`` and replicate across ``tp_in``, so the all-to-all never
    crosses the fast intra-node links redundantly (docs/topology.md). This
    is what makes E < tp configurations first-class: E=4 on an 8-way 2×4
    mesh is plain E % tp_out == 0 expert sharding. x: (B, S, d)
    sequence-sharded. Returns FFN(LN(x)) (residual handled by the caller)
    and the load-balancing aux loss.

    The routing/expert/combine math is shared with the whole-block IR path
    (:func:`sp_block`) via the :func:`_moe_graph_fns` closures."""
    from repro.models.layers import apply_norm

    m = cfg.moe
    E = m.num_experts
    ring = tpc.route_ring
    M = tpc.tp_axes
    cais = tpc.cais
    has_gate = "w_gate" in params
    route_fn, expert_fn, unroute_fn = _moe_graph_fns(
        cfg, ring, has_gate, route_axis=tpc.route_axis)

    def local(x, ns, router, wu, wg, wd):
        xn = apply_norm(norm_kind, {"scale": ns}, x)
        send, combine, aux = route_fn(xn, router)
        ws = (wu, wg, wd) if has_gate else (wu, wd)
        ret = tpc.backend.a2a_expert_ffn(
            send, lambda chunk: expert_fn(chunk, *ws), M, cais)
        out = unroute_fn(ret, combine, xn)
        if m.dense_residual_d_ff:
            from repro.models.ffn import mlp_forward
            out = out + mlp_forward(params["dense"], xn, cfg.act)
        # aux leaves sharded over (batch, model) — the per-shard statistics
        # genuinely differ per data shard (same convention as sp_period)
        return out, aux[None]

    dtype = x.dtype
    wu = params["w_up"].astype(dtype)
    wg = params["w_gate"].astype(dtype) if has_gate else \
        jnp.zeros_like(params["w_up"], dtype)
    wd = params["w_down"].astype(dtype)
    e_spec = (tpc.route_axis, None, None) if E % ring == 0 \
        else (None, None, None)
    out, aux = _smap(
        tpc, local,
        in_specs=[(BATCH, M, None), (None,), (None, None),
                  e_spec, e_spec, e_spec],
        out_specs=[(BATCH, M, None), (BATCH, M)])(
            x, norm_scale, params["router"], wu, wg, wd)
    return out, jnp.mean(aux)


# ---------------------------------------------------------------------------
# Whole-block execution: ONE dataflow graph per transformer block
# ---------------------------------------------------------------------------


def _moe_graph_fns(cfg, ring: int, has_gate: bool, route_axis=MODEL):
    """Closures for the MoE expert path (route / a2a expert compute /
    unroute) — the single home of this math, used both as IR node ``fn``s
    by :func:`sp_block`'s graph and composed directly by
    :func:`sp_moe_ffn`. ``ring`` is the size of the all-to-all ring and
    ``route_axis`` its mesh axis name: the full model axis on a flat mesh,
    the slow ``tp_out`` axis on a hierarchical 2D mesh (grouped EP). Owner
    mapping as documented on ``sp_moe_ffn``: ring rank j owns experts
    [j·E_loc, (j+1)·E_loc) when E ≥ ring; when E < ring expert e lives on
    rank e·(ring/E) (replicated weights sliced per owner, zero-capacity
    padding elsewhere)."""
    from repro.models.ffn import _top2_dispatch
    from repro.models.layers import activation

    m = cfg.moe
    E = m.num_experts
    E_loc = max(E // ring, 1)

    def route_fn(xn, router):
        B, S_loc, d = xn.shape
        t = xn.reshape(B * S_loc, d)
        T = t.shape[0]
        logits = t.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, -1)
        cap = max(1, int(T * m.top_k / E * m.capacity_factor))
        dispatch, combine, aux = _top2_dispatch(probs[None], cap)
        dispatch, combine = dispatch[0], combine[0]     # (T, E, cap)
        # send[j]: (E_loc·cap, d) tokens for the experts device j owns
        de = jnp.einsum("tec,td->ecd", dispatch.astype(t.dtype), t)
        if E >= ring:
            send = de.reshape(ring, E_loc * cap, d)
        else:
            # owner(e) = e·(ring/E); other ranks get zero-capacity padding
            stride = ring // E
            send = jnp.zeros((ring, cap, d), t.dtype)
            send = send.at[::stride].set(de)
        return send, combine, aux.astype(jnp.float32)[None]

    def expert_fn(chunk, wu, *rest):
        # chunk: (E_loc·cap, d) → per-local-expert gated FFN
        wg = rest[0] if has_gate else None
        wd = rest[-1]
        if E < ring:
            # replicated weights: slice this owner's single expert
            eidx = jax.lax.axis_index(route_axis) // (ring // E)
            wu = jax.lax.dynamic_index_in_dim(wu, eidx, 0, keepdims=True)
            wd = jax.lax.dynamic_index_in_dim(wd, eidx, 0, keepdims=True)
            if has_gate:
                wg = jax.lax.dynamic_index_in_dim(wg, eidx, 0, keepdims=True)
        c = chunk.reshape(E_loc, -1, chunk.shape[-1])
        h = jnp.einsum("ecd,edf->ecf", c, wu)
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", c, wg)
            h = activation(cfg.act, g) * h
        else:
            h = activation(cfg.act, h)
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        return out.reshape(chunk.shape)

    def unroute_fn(ret, combine, xn):
        B, S_loc, d = xn.shape
        cap = combine.shape[-1]
        if E >= ring:
            eout = ret.reshape(E, cap, d)
        else:
            eout = ret[::ring // E]
        y = jnp.einsum("tec,ecd->td", combine.astype(ret.dtype), eout)
        return y.reshape(B, S_loc, d)

    return route_fn, expert_fn, unroute_fn


def _block_graph_fragment(tpc: TPContext, params, cfg, kind: str, idx: int,
                          src: str, prefix_len: int = 0,
                          dtype=jnp.float32, seq_sharded: bool = True):
    """One transformer block as a period-graph fragment: nodes chained from
    value ``src``, every node name and weight key namespaced ``b{idx}.``.
    Returns (nodes, out_value, aux_value_or_None, weights, specs) —
    ``weights`` maps the namespaced keys to local param arrays and ``specs``
    to their shard_map PartitionSpec entries."""
    p = f"b{idx}."
    tp = tpc.tp
    M = tpc.tp_axes
    m = params["mixer"]
    kv_sharded = cfg.num_kv_heads % tp == 0
    window = cfg.window if kind == "swa" else 0
    core = _attention_core_fn(cfg, tp, window=window, prefix_len=prefix_len,
                              axis=M)

    kv_spec = (None, M) if kv_sharded else (None, None)
    weights = {
        p + "scale1": params["norm1"]["scale"].astype(dtype),
        p + "wq": m["wq"].astype(dtype), p + "wk": m["wk"].astype(dtype),
        p + "wv": m["wv"].astype(dtype), p + "wo": m["wo"].astype(dtype),
        p + "scale2": params["norm2"]["scale"].astype(dtype),
    }
    specs = {
        p + "scale1": (None,), p + "wq": (None, M), p + "wk": kv_spec,
        p + "wv": kv_spec, p + "wo": (M, None), p + "scale2": (None,),
    }

    f = params["ffn"]
    has_gate = "w_gate" in f
    moe = cfg.moe is not None
    if moe:
        ring = tpc.route_ring
        assert seq_sharded, \
            "MoE blocks run only on the sequence-sharded period path"
        assert cfg.moe.num_experts % ring == 0, \
            "sp_block MoE path requires E % route_ring == 0 " \
            "(see tp_applicable)"
        route_fn, expert_fn, unroute_fn = _moe_graph_fns(
            cfg, ring, has_gate, route_axis=tpc.route_axis)
        weights[p + "router"] = f["router"]             # stays float32
        specs[p + "router"] = (None, None)
        e_keys = tuple(p + kk for kk in ("w_up",)
                       + (("w_gate",) if has_gate else ()) + ("w_down",))
        for kkey in e_keys:
            weights[kkey] = f[kkey[len(p):]].astype(dtype)
            # grouped EP on a 2D mesh: experts shard over tp_out only and
            # replicate across tp_in (gradients psum over tp_in in
            # local_bwd's missing-axes pass)
            specs[kkey] = (tpc.route_axis, None, None)
        dense_fn, d_keys = None, ()
        if cfg.moe.dense_residual_d_ff:
            dm = f["dense"]
            dense_gate = "w_gate" in dm
            d_keys = tuple(p + kk for kk in ("d_up",)
                           + (("d_gate",) if dense_gate else ())
                           + ("d_down",))
            weights[p + "d_up"] = dm["w_up"].astype(dtype)
            if dense_gate:
                weights[p + "d_gate"] = dm["w_gate"].astype(dtype)
            weights[p + "d_down"] = dm["w_down"].astype(dtype)
            for kkey in d_keys:
                specs[kkey] = (None, None)
            from repro.models.layers import activation

            def dense_fn(xn, du, *drest):
                dg = drest[0] if dense_gate else None
                dd = drest[-1]
                h = xn @ du
                if dense_gate:
                    h = activation(cfg.act, xn @ dg) * h
                else:
                    h = activation(cfg.act, h)
                return h @ dd

        nodes, out, aux = _moe_block_nodes(core, route_fn, expert_fn,
                                           unroute_fn, e_keys, dense_fn,
                                           d_keys, p=p, src=src)
    else:
        nodes, out = _dense_block_nodes(core, has_gate, cfg.act, p=p,
                                        src=src, seq_sharded=seq_sharded)
        aux = None
        weights[p + "w_up"] = f["w_up"].astype(dtype)
        specs[p + "w_up"] = (None, M)
        if has_gate:
            weights[p + "w_gate"] = f["w_gate"].astype(dtype)
            specs[p + "w_gate"] = (None, M)
        weights[p + "w_down"] = f["w_down"].astype(dtype)
        specs[p + "w_down"] = (M, None)
    return nodes, out, aux, weights, specs


def _period_graph(tpc: TPContext, params_seq, cfg, kinds: Sequence[str],
                  prefix_len: int = 0, dtype=jnp.float32,
                  seq_sharded: bool = True):
    """The single-chain period graph :func:`sp_period` executes: every block
    in ``kinds`` chained through per-block ``b{i}.`` namespaces from input
    ``x``. Returns (graph, weights dict, specs dict, aux value names)."""
    nodes = [df.Node("x", "input")]
    weights, specs, aux_vals = {}, {}, []
    src = "x"
    for i, (params, kind) in enumerate(zip(params_seq, kinds)):
        ns, src, aux, w, s = _block_graph_fragment(
            tpc, params, cfg, kind, i, src, prefix_len=prefix_len,
            dtype=dtype, seq_sharded=seq_sharded)
        clash = sorted(set(w) & set(weights))
        if clash:
            raise df.GraphError(
                f"period graph weight key collision on {clash[0]!r} "
                f"(block {i})")
        nodes += ns
        weights.update(w)
        specs.update(s)
        if aux is not None:
            aux_vals.append(aux)
    graph = df.Graph(nodes, outputs=(src,) + tuple(aux_vals))
    return graph, weights, specs, tuple(aux_vals)


def microbatch_period_graph(base: df.Graph, num_microbatches: int) -> df.Graph:
    """``num_microbatches`` independent copies of a single-chain period graph
    merged into ONE graph (``mb{i}.``-prefixed values, SHARED weight keys) —
    the in-model microbatch split. After ``optimize()`` pass 3 cross-pairs
    collectives from different chains (``overlap_asym``), which a
    straight-line period can never expose. ``num_microbatches=1`` returns
    ``base`` unchanged (the unsplit path, bit-identical)."""
    if num_microbatches <= 1:
        return base
    return df.merge_graphs([base] * num_microbatches, share_weights=True)


def resolve_microbatches(tpc: TPContext, x,
                         requested: Union[int, str, None] = None,
                         moe: bool = False) -> int:
    """The effective period-graph batch split for activation ``x``
    ((B, S, d), global). ``requested=None`` defers to
    ``tpc.num_microbatches``; ``"auto"`` asks
    :func:`repro.core.coordination.plan_microbatches` with the per-device
    batch and the full gathered-activation payload. The result is clamped
    to the largest value that divides the per-device batch (1 = unsplit).

    ``moe=True`` (the period contains MoE blocks) disables ``"auto"``
    splitting: the MoE load-balance aux loss is a per-(micro)batch
    statistic that is NOT linear over sub-batches, so splitting changes
    the training objective's aux term — that trade-off must be an explicit
    integer opt-in, never a silent default."""
    req = tpc.num_microbatches if requested is None else requested
    b_loc = max(int(x.shape[0]) // max(sharding.dp_size(tpc.mesh), 1), 1)
    if req == "auto":
        if moe:
            return 1
        payload = b_loc * int(x.shape[1]) * int(x.shape[2]) * \
            np.dtype(x.dtype).itemsize
        n_in, n_out = tpc.topology
        if n_out > 1:
            # 2D mesh: the slow inter-node tier dominates the collective
            # time the split amortizes — plan against the tp_out ring with
            # the inter-tier α-β model and the per-node payload (the outer
            # exchange moves 1/tp_in of the gathered activation per rank)
            mb = coordination.plan_microbatches(
                b_loc, float(payload) / max(n_in, 1), n_out,
                bidirectional=tpc.cais.bidirectional,
                hw=tpc.hw.inter_tier())
        else:
            mb = coordination.plan_microbatches(b_loc, float(payload),
                                                tpc.tp,
                                                bidirectional=
                                                tpc.cais.bidirectional,
                                                hw=tpc.hw)
    else:
        mb = int(req)
    mb = max(1, min(mb, b_loc))
    while b_loc % mb:
        mb -= 1
    return mb


def _core_comp_hints(cfg, kinds: Sequence[str], batch: int, seq: int
                     ) -> Dict[str, float]:
    """Planner ``comp_hints`` for a single-chain period graph: the attention
    cores (``b{i}.o`` custom nodes) and the routed expert FFNs (``b{i}.eout``
    a2a_ffn nodes) are the op classes whose cost the lowering cannot read
    off GEMM weight shapes, so their FLOPs come from
    :mod:`repro.models.counting`. Keys are base-graph node names
    (per-replica ``batch``, like the planner's value shapes);
    :func:`repro.plan.search.microbatch_comp_hints` re-prefixes and
    re-scales them per microbatch chain, and :func:`_bwd_planner` doubles
    each hint for the matching ``adj.`` node."""
    from repro.models.counting import attention_core_flops, expert_ffn_flops

    flops = attention_core_flops(cfg, batch, seq)
    hints = {f"b{i}.o": flops for i in range(len(kinds))}
    if cfg.moe is not None:
        ef = expert_ffn_flops(cfg, batch, seq)
        hints.update({f"b{i}.eout": ef
                      for i, k in enumerate(kinds) if k == "moe"})
    return hints


def _plan_period(tpc: TPContext, base: df.Graph, weights, x,
                 requested: Union[int, str, None], moe: bool,
                 comp_hints: Optional[Dict[str, float]] = None):
    """The (num_microbatches, pass-3 planner) decision for one period graph
    under ``tpc.planner``.

    ``"greedy"`` keeps the α-β heuristic split (:func:`resolve_microbatches`)
    and the nearest-first pairing (planner None). ``"perfsim"`` hands the
    whole decision to :func:`repro.plan.search.period_planner`: microbatch
    candidates (the α-β path's power-of-two menu; an explicit integer
    request stays fixed — the planner then only decides pairing/chunking;
    MoE periods never auto-split, same contract as the greedy path) are
    scored by simulated makespan together with pass-3 pairings and chunk
    counts, memoized in the process-wide plan cache."""
    if tpc.planner != "perfsim":
        return resolve_microbatches(tpc, x, requested, moe), None
    from repro import plan as plan_mod

    req = tpc.num_microbatches if requested is None else requested
    b_loc = max(int(x.shape[0]) // max(sharding.dp_size(tpc.mesh), 1), 1)
    if req == "auto":
        cands = (1,) if moe else tuple(
            m for m in (1, 2, 4) if m <= b_loc and b_loc % m == 0)
    else:
        cands = (resolve_microbatches(tpc, x, requested, moe),)
    x_shape = (b_loc, int(x.shape[1]), int(x.shape[2]))
    plan, pairer = plan_mod.period_planner(
        base, x_shape=x_shape,
        weight_shapes={k: tuple(v.shape) for k, v in weights.items()},
        dtype_bytes=np.dtype(x.dtype).itemsize, tp=tpc.tp,
        backend=tpc.mode, mb_candidates=cands, hw=tpc.hw,
        n_outer=tpc.topology[1],
        cache=plan_mod.default_cache(), comp_hints=comp_hints)
    return plan.num_microbatches, pairer


def _bwd_planner(tpc: TPContext, tg: "df.TrainingGraph", weights, x,
                 mb: int, hints: Optional[Dict[str, float]]):
    """Pass-3 planner for the merged fwd+bwd training graph. ``"greedy"``
    keeps the deterministic nearest-pair policy (None). ``"perfsim"`` builds
    a fresh :class:`repro.plan.PerfsimPlanner` over the training graph's
    value shapes (per-chain ``x`` AND cotangent seeds) and the weight table
    extended with the derived transposed keys, with backward attention-core
    adjoints hinted at 2× forward FLOPs."""
    if tpc.planner != "perfsim":
        return None
    from repro import plan as plan_mod

    b_loc = max(int(x.shape[0]) // max(sharding.dp_size(tpc.mesh), 1), 1)
    per = (max(b_loc // mb, 1), int(x.shape[1]), int(x.shape[2]))
    chains = ["x"] if mb == 1 else [f"mb{i}.x" for i in range(mb)]
    vshapes = {c: per for c in chains}
    # cotangent seeds are activation-shaped except the MoE aux-loss
    # statistics, which are scalar side-outputs
    vshapes.update({gi: ((1,) if gi.endswith("aux") else per)
                    for gi in tg.grad_inputs})
    wshapes = {k: tuple(v.shape) for k, v in weights.items()}
    wshapes.update(df.derived_weight_shapes(tg.graph, wshapes))
    bh = {}
    for k, f in (hints or {}).items():
        for pfx in ([""] if mb == 1 else [f"mb{i}." for i in range(mb)]):
            bh[pfx + k] = f / mb
            bh["adj." + pfx + k] = 2.0 * f / mb
    return plan_mod.PerfsimPlanner(
        value_shapes=vshapes, weight_shapes=wshapes,
        dtype_bytes=np.dtype(x.dtype).itemsize,
        fabric=plan_mod.fabric_from_hw(tpc.hw, max(tpc.tp, 2),
                                       n_outer=tpc.topology[1]),
        backend=tpc.mode, num_microbatches=mb,
        cache=plan_mod.default_cache(), comp_hints=bh or None)


# op-sets already warned about, so a training loop re-tracing the same
# period shape doesn't repeat the message every step
_GRAPH_BWD_WARNED: set = set()


def _warn_graph_bwd_fallback(bad_ops: Sequence[str]) -> None:
    """Warn ONCE per offending op-set when ``graph_backward=True`` cannot
    build the backward graph and falls back to JAX autodiff of the executed
    forward — naming the ops without adjoints so the fallback is never
    silent."""
    key = tuple(bad_ops)
    if key in _GRAPH_BWD_WARNED:
        return
    _GRAPH_BWD_WARNED.add(key)
    warnings.warn(
        "TPConfig(graph_backward=True): period graph has no declared "
        f"adjoint for op(s) {', '.join(repr(o) for o in bad_ops)}; "
        "falling back to JAX autodiff of the executed forward "
        "(docs/training.md)", UserWarning, stacklevel=3)


def sp_period(tpc: TPContext, x, params_seq, cfg, kinds: Sequence[str], *,
              opts: Optional[SPOptions] = None, **kw):
    """A whole ``layer_pattern`` period — every block in ``kinds`` with its
    params from ``params_seq`` — built as ONE dataflow graph, optimized, and
    executed in ONE ``shard_map``. This is the unit the paper's graph-level
    optimizer actually evaluates: with ≥2 blocks, pass 2 fuses the
    block→block seam (block k's FFN-out RS → residual → block k+1's LN1 →
    QKV shared gather, and the MoE rs → residual → ln → route variant) that
    no per-block graph can see, and pass 3's deterministic
    nearest-pair policy co-schedules whatever independent RS/AG pairs the
    merged graph exposes. Options via ``opts`` / :class:`SPOptions`
    keywords.

    ``num_microbatches`` (default: the :class:`TPContext` knob; ``"auto"``
    → :func:`resolve_microbatches`) splits the batch axis into that many
    independent per-microbatch chains merged into the SAME graph with
    shared weights — a straight-line period is fully serialized after
    pass-2 fusion, so this split is what gives pass 3 the independent
    cross-chain pairs it turns into ``overlap_asym`` in the model path.
    The split, per-chain execution, and output re-concatenation all happen
    inside the one ``shard_map``. Block OUTPUTS are exactly preserved
    (≤1e-6, pinned in ``multidev_checks``). The MoE aux loss is NOT: each
    chain routes with its own capacity and the load-balance statistic is
    not linear over sub-batches, so the split period reports the mean of
    per-chain aux values, which differs from the full-batch statistic.
    ``"auto"`` therefore never splits an MoE period — an explicit integer
    is the opt-in that accepts the changed aux term.

    When ``tpc.graph_backward`` is set (the default) and every op of the
    pass-2-fused period declares an adjoint
    (:func:`repro.core.dataflow.supports_backward`), execution is wrapped in
    ``jax.custom_vjp``: the backward is BUILT as a dataflow graph too
    (:func:`repro.core.dataflow.build_training_graph` over the pass-2-fused
    forward), optimized by the same pass-3 planner, and executed in one
    backward ``shard_map`` — so with ``num_microbatches ≥ 2`` pass 3 pairs
    one chain's backward grad reduce-scatter against another chain's
    forward-recompute gather (``overlap_asym`` spanning fwd and bwd), the
    overlap class the paper wins its training speedup from. This covers
    MoE periods (``route``/``a2a_ffn``/``unroute`` adjoints, with the
    aux-loss cotangent seeded per chain) and the replicated-activation
    decode/ragged layout (``seq_sharded=False``: ``gemm_col``/``gemm_ar``
    adjoints, S=1 included). A period whose graph still carries an op with
    no adjoint falls back to JAX autodiff of the executed forward with a
    once-per-op-set ``UserWarning`` naming the ops; the non-explicit
    ``auto`` backend always takes the autodiff path (there is no explicit
    backward schedule to build for it). See ``docs/training.md``.

    x: (B, S, d), sequence-sharded when ``seq_sharded`` (the training path)
    or replicated when not (the decode/ragged-S allreduce path, dense blocks
    only). Returns (period output, summed aux loss)."""
    o = _sp_opts(opts, kw)
    norm_kind = o.norm_kind
    dtype = x.dtype
    M = tpc.tp_axes
    base, weights, specs, aux_vals = _period_graph(
        tpc, params_seq, cfg, kinds, prefix_len=o.prefix_len, dtype=dtype,
        seq_sharded=o.seq_sharded)
    b_loc = max(int(x.shape[0]) // max(sharding.dp_size(tpc.mesh), 1), 1)
    hints = _core_comp_hints(cfg, kinds, b_loc, int(x.shape[1]))
    mb, planner = _plan_period(tpc, base, weights, x, o.num_microbatches,
                               moe=bool(aux_vals), comp_hints=hints)
    merged = microbatch_period_graph(base, mb)
    graph = df.optimize(merged, planner=planner)
    names = list(weights)
    n_aux = len(aux_vals)

    def local(x, *ws):
        wmap = dict(zip(names, ws))
        if mb == 1:
            res = df.execute(graph, {"x": x}, wmap, axis=M,
                             cais=tpc.cais, norm=norm_kind,
                             backend=tpc.backend)
            if n_aux:
                # aux leaves the shard_map sharded over (batch, model): the
                # per-shard statistics genuinely differ per data shard, so a
                # replicated out-spec would be a lie (check_vma=False never
                # verifies it) and its autodiff transpose ill-defined
                res = tuple(res[:1]) + tuple(a[None] for a in res[1:])
            return res
        res = df.execute(
            graph,
            {f"mb{i}.x": xi
             for i, xi in enumerate(jnp.split(x, mb, axis=0))},
            wmap, axis=M, cais=tpc.cais, norm=norm_kind,
            backend=tpc.backend)
        per = 1 + n_aux
        out = jnp.concatenate([res[i * per] for i in range(mb)], axis=0)
        auxes = tuple(
            (sum(res[i * per + 1 + j] for i in range(mb)) / mb)[None]
            for j in range(n_aux))
        return (out,) + auxes

    x_spec = (BATCH, M, None) if o.seq_sharded else (BATCH, None, None)
    in_specs = [x_spec] + [specs[k] for k in names]
    out_specs = [x_spec] + [(BATCH, M)] * n_aux
    fwd_call = _smap(tpc, local, in_specs, out_specs)

    use_graph_bwd = (tpc.graph_backward
                     and getattr(tpc.backend, "explicit", True))
    if use_graph_bwd:
        # the backward is declared against the pass-2-fused forward (it
        # re-exposes every activation the adjoints need); pass 3 then runs
        # on the MERGED fwd+bwd graph so pairing can span both directions
        g2 = df.fuse_sublayer_chain(df.fuse_shared_gather(
            df.fuse_compute_aware(merged)))
        bad = sorted({n.op for n in g2.nodes if n.op not in df.ADJOINTS})
        if bad:
            _warn_graph_bwd_fallback(bad)
            use_graph_bwd = False
    if not use_graph_bwd:
        res = fwd_call(x, *weights.values())
        aux = jnp.float32(0.0)
        for a in res[1:]:
            aux = aux + jnp.mean(a)
        return res[0], aux

    tg = df.build_training_graph(g2, norm=norm_kind)
    bwd_graph = df.optimize(tg.graph, planner=_bwd_planner(
        tpc, tg, weights, x, mb, hints))
    chains = ["x"] if mb == 1 else [f"mb{i}.x" for i in range(mb)]
    # weight grads leave the shard_map through specs that omit the batch
    # axes (and MODEL for replicated weights), so the partial sums must be
    # completed inside
    batch_axes = tuple(a for a in _BATCH_AXES
                       if a in tpc.mesh.axis_names)
    # every TP mesh axis a weight's spec does NOT mention replicates that
    # weight there, so its gradient partial-sums must psum over it — on a
    # 2D mesh this is how grouped-EP expert grads reduce over tp_in only
    tp_names = M if isinstance(M, tuple) else (M,)
    tp_names = tuple(a for a in tp_names if a in tpc.mesh.axis_names)
    grad_psum_axes = {}
    for k in names:
        if not o.seq_sharded:
            # replicated-activation layout (decode/ragged): every device
            # sees the full batch×seq, so replicated-weight grads are
            # already complete — a psum over the TP axes would overcount
            # by the ring size
            grad_psum_axes[k] = ()
            continue
        mentioned = set()
        for e in specs[k]:
            if isinstance(e, (tuple, list)):
                mentioned.update(e)
            elif e is not None:
                mentioned.add(e)
        grad_psum_axes[k] = tuple(a for a in tp_names if a not in mentioned)

    def local_bwd(x, gy, *rest):
        gauxes, ws = rest[:n_aux], rest[n_aux:]
        wmap = df.derived_weights(bwd_graph, dict(zip(names, ws)))
        vals = {}
        xs = jnp.split(x, mb, axis=0) if mb > 1 else [x]
        gys = jnp.split(gy, mb, axis=0) if mb > 1 else [gy]
        vals.update(zip(chains, xs))
        # cotangent seeds in graph-output order: per chain (d.out,
        # d.aux...). The fwd reports the mean of per-chain aux values, so
        # each chain's aux seed carries 1/mb of the aux cotangent; gauxes
        # arrive (batch, model)-sharded, so ga[0] is exactly this device's
        # slice of the aux cotangent — no replication ambiguity.
        seeds = []
        for i in range(mb):
            seeds.append(gys[i])
            seeds.extend(ga[0] / mb for ga in gauxes)
        vals.update(zip(tg.grad_inputs, seeds))
        res = df.execute(bwd_graph, vals, wmap, axis=M, cais=tpc.cais,
                         norm=norm_kind, backend=tpc.backend)
        got = dict(zip(bwd_graph.outputs, res))
        dxs = [got[tg.dx[c]] for c in chains]
        dx = jnp.concatenate(dxs, axis=0) if mb > 1 else dxs[0]
        dws = []
        for k, w in zip(names, ws):
            parts = [got[v] for v in tg.dweights.get(k, ())]
            dw = parts[0] if parts else jnp.zeros_like(w)
            for p_ in parts[1:]:
                dw = dw + p_
            if batch_axes:
                dw = jax.lax.psum(dw, batch_axes)
            if grad_psum_axes[k]:
                dw = jax.lax.psum(dw, grad_psum_axes[k])
            dws.append(dw.astype(w.dtype))
        return (dx.astype(x.dtype),) + tuple(dws)

    bwd_call = _smap(tpc, local_bwd,
                     [x_spec, x_spec] + [(BATCH, M)] * n_aux
                     + [specs[k] for k in names],
                     [x_spec] + [specs[k] for k in names])

    @jax.custom_vjp
    def period(x, *ws):
        return fwd_call(x, *ws)

    def period_fwd(x, *ws):
        return fwd_call(x, *ws), (x, ws)

    def period_bwd(saved, gys):
        xr, ws = saved
        out = bwd_call(xr, gys[0], *gys[1:], *ws)
        return (out[0],) + tuple(out[1:])

    period.defvjp(period_fwd, period_bwd)
    res = period(x, *tuple(weights.values()))
    aux = jnp.float32(0.0)
    for a in res[1:]:
        aux = aux + jnp.mean(a)
    return res[0], aux


def _serve_attention_core_fn(cfg, tp: int, window: int = 0,
                             axis=MODEL) -> Callable:
    """The paged-serving attention core as a multi-output ``custom`` IR node
    fn: besides q/k/v it takes the :class:`repro.models.attention.KVView`
    arrays (block tables, positions, context lens) and this block's KV pools
    as graph *inputs*, scatters the step's K/V through the block tables,
    attends over each row's gathered context, and returns the updated pools
    as extra outputs — the same multi-output convention as the MoE ``route``
    node. KV-head handling mirrors :func:`_attention_core_fn`: sharded pools
    hold this device's heads; replicated (GQA) pools are written identically
    on every device and sliced per-device for the core."""
    from repro.models.attention import (attention_core, paged_lookup,
                                        paged_update)
    from repro.models.layers import apply_rope

    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kv_sharded = Hkv % tp == 0

    def core(q, k, v, bt, qpos, ctx, kp, vp):
        B_, S = q.shape[0], q.shape[1]
        H_loc = max(H // tp, 1)
        Hkv_loc = max(Hkv // tp, 1) if kv_sharded else Hkv
        pos = jnp.maximum(qpos, 0)
        q = apply_rope(q.reshape(B_, S, H_loc, dh), pos, cfg.rope_theta)
        k = apply_rope(k.reshape(B_, S, Hkv_loc, dh), pos, cfg.rope_theta)
        v = v.reshape(B_, S, Hkv_loc, dh)
        kp, vp = paged_update(kp, vp, k, v, bt, qpos)
        kk, vv, kv_pos = paged_lookup(kp, vp, bt, ctx)
        if not kv_sharded:
            g = H // Hkv                    # q heads per kv head
            need = max(H_loc // g, 1)
            start = (sharding.shard_map_axis_index(axis) * H_loc) // g
            kk = jax.lax.dynamic_slice_in_dim(kk, start, need, axis=2)
            vv = jax.lax.dynamic_slice_in_dim(vv, start, need, axis=2)
        o = attention_core(q, kk, vv, q_positions=qpos, kv_positions=kv_pos,
                           causal=True, window=window)
        return o.reshape(B_, S, H_loc * dh), kp, vp

    return core


def _serve_block_fragment(tpc: TPContext, params, cfg, kind: str, idx: int,
                          src: str, dtype=jnp.float32):
    """One dense block as a serve-period graph fragment (replicated
    activations, allreduce schedule): like :func:`_block_graph_fragment`
    with ``seq_sharded=False``, except the attention core is the
    pool-carrying :func:`_serve_attention_core_fn` node. Returns
    (nodes, out_value, weights, specs)."""
    p = f"b{idx}."
    tp = tpc.tp
    M = tpc.tp_axes
    m = params["mixer"]
    kv_sharded = cfg.num_kv_heads % tp == 0
    window = cfg.window if kind == "swa" else 0
    core = _serve_attention_core_fn(cfg, tp, window=window, axis=M)

    kv_spec = (None, M) if kv_sharded else (None, None)
    weights = {
        p + "scale1": params["norm1"]["scale"].astype(dtype),
        p + "wq": m["wq"].astype(dtype), p + "wk": m["wk"].astype(dtype),
        p + "wv": m["wv"].astype(dtype), p + "wo": m["wo"].astype(dtype),
        p + "scale2": params["norm2"]["scale"].astype(dtype),
    }
    specs = {
        p + "scale1": (None,), p + "wq": (None, M), p + "wk": kv_spec,
        p + "wv": kv_spec, p + "wo": (M, None), p + "scale2": (None,),
    }
    nodes = [
        df.Node(f"{p}ln1", "layernorm", (src,), (f"{p}scale1",)),
        df.Node(f"{p}q", "gemm_col", (f"{p}ln1",), (f"{p}wq",)),
        df.Node(f"{p}k", "gemm_col", (f"{p}ln1",), (f"{p}wk",)),
        df.Node(f"{p}v", "gemm_col", (f"{p}ln1",), (f"{p}wv",)),
        df.Node(f"{p}o", "custom",
                (f"{p}q", f"{p}k", f"{p}v", "bt", "qpos", "ctx",
                 f"{p}kp", f"{p}vp"),
                outputs=(f"{p}o", f"{p}kpn", f"{p}vpn"), fn=core),
        df.Node(f"{p}proj", "gemm_row", (f"{p}o",), (f"{p}wo",)),
        df.Node(f"{p}rs1", "allreduce", (f"{p}proj",)),
        df.Node(f"{p}r1", "residual", (f"{p}rs1", src)),
        df.Node(f"{p}ln2", "layernorm", (f"{p}r1",), (f"{p}scale2",)),
    ]
    f = params["ffn"]
    has_gate = "w_gate" in f
    nodes += _ffn_chain_nodes(f"{p}ln2", f"{p}rs2", has_gate, cfg.act,
                              tag="2", p=p, seq_sharded=False)
    nodes.append(df.Node(f"{p}r2", "residual", (f"{p}rs2", f"{p}r1")))
    weights[p + "w_up"] = f["w_up"].astype(dtype)
    specs[p + "w_up"] = (None, M)
    if has_gate:
        weights[p + "w_gate"] = f["w_gate"].astype(dtype)
        specs[p + "w_gate"] = (None, M)
    weights[p + "w_down"] = f["w_down"].astype(dtype)
    specs[p + "w_down"] = (M, None)
    return nodes, f"{p}r2", weights, specs


def sp_serve_period(tpc: TPContext, x, params_seq, cfg,
                    kinds: Sequence[str], pools_seq, view, *,
                    norm_kind: str = "rmsnorm"):
    """A whole period of a mixed prefill+decode *serving* step as ONE
    dataflow graph in ONE ``shard_map`` — the serving analogue of
    :func:`sp_period`. The activation stays replicated (decode S=1 and
    chunked-prefill S % tp ≠ 0 both fit), so pass 1 fuses every
    out-projection/FFN-down reduction into backend-dispatched ``gemm_ar`` —
    TP is never silently unsharded under serving. The paged KV pools, block
    tables, and position/context arrays enter the graph as extra inputs of
    each block's attention ``custom`` node, and the updated pools leave as
    graph outputs. With ``planner="perfsim"`` the optimized schedule comes
    from the simulated-makespan search over the serve-period graph itself
    (value shapes include the real pool/table shapes), through the plan
    cache. Pools are shared unbatched state: callers must run with dp == 1
    (gated in ``models.transformer._blocks_step``).

    x: (B, S_step, d) replicated; ``pools_seq`` one ``{"k", "v"}`` pool dict
    per block; ``view`` a :class:`repro.models.attention.KVView`. Returns
    (period output, new pools list)."""
    dtype = x.dtype
    n = len(kinds)
    nodes = [df.Node("x", "input"), df.Node("bt", "input"),
             df.Node("qpos", "input"), df.Node("ctx", "input")]
    weights: Dict[str, jnp.ndarray] = {}
    specs: Dict[str, tuple] = {}
    src = "x"
    for i, (params, kind) in enumerate(zip(params_seq, kinds)):
        nodes += [df.Node(f"b{i}.kp", "input"), df.Node(f"b{i}.vp", "input")]
        ns, src, w, s = _serve_block_fragment(tpc, params, cfg, kind, i, src,
                                              dtype=dtype)
        nodes += ns
        weights.update(w)
        specs.update(s)
    pool_outs = tuple(v for i in range(n)
                      for v in (f"b{i}.kpn", f"b{i}.vpn"))
    base = df.Graph(nodes, outputs=(src,) + pool_outs)

    planner = None
    b_loc = max(int(x.shape[0]) // max(sharding.dp_size(tpc.mesh), 1), 1)
    hints = _core_comp_hints(cfg, kinds, b_loc, int(x.shape[1]))
    if tpc.planner == "perfsim":
        from repro import plan as plan_mod

        vshapes = {"x": (b_loc,) + tuple(int(d) for d in x.shape[1:]),
                   "bt": tuple(view.block_tables.shape),
                   "qpos": tuple(view.positions.shape),
                   "ctx": tuple(view.context_lens.shape)}
        for i, pool in enumerate(pools_seq):
            vshapes[f"b{i}.kp"] = tuple(pool["k"].shape)
            vshapes[f"b{i}.vp"] = tuple(pool["v"].shape)
        planner = plan_mod.PerfsimPlanner(
            value_shapes=vshapes,
            weight_shapes={k: tuple(v.shape) for k, v in weights.items()},
            dtype_bytes=np.dtype(x.dtype).itemsize,
            fabric=plan_mod.fabric_from_hw(tpc.hw, max(tpc.tp, 2),
                                           n_outer=tpc.topology[1]),
            backend=tpc.mode, cache=plan_mod.default_cache(),
            comp_hints=hints)
    graph = df.optimize(base, planner=planner)
    names = list(weights)

    def local(x, bt, qpos, ctx, *rest):
        pools, ws = rest[:2 * n], rest[2 * n:]
        vals = {"x": x, "bt": bt, "qpos": qpos, "ctx": ctx}
        for i in range(n):
            vals[f"b{i}.kp"] = pools[2 * i]
            vals[f"b{i}.vp"] = pools[2 * i + 1]
        return df.execute(graph, vals, dict(zip(names, ws)),
                          axis=tpc.tp_axes, cais=tpc.cais, norm=norm_kind,
                          backend=tpc.backend)

    kv_sharded = cfg.num_kv_heads % tpc.tp == 0
    pool_spec = (None, None, tpc.tp_axes, None) if kv_sharded \
        else (None, None, None, None)
    x_spec = (BATCH, None, None)
    in_specs = ([x_spec, (None, None), (None, None), (None,)]
                + [pool_spec] * (2 * n) + [specs[k] for k in names])
    out_specs = [x_spec] + [pool_spec] * (2 * n)
    flat_pools = [p[kk] for p in pools_seq for kk in ("k", "v")]
    res = _smap(tpc, local, in_specs, out_specs)(
        x, view.block_tables, view.positions, view.context_lens,
        *flat_pools, *weights.values())
    new_pools = [{"k": res[1 + 2 * i], "v": res[2 + 2 * i]}
                 for i in range(n)]
    return res[0], new_pools


def sp_block(tpc: TPContext, x, params, cfg, kind: str = "attn", *,
             opts: Optional[SPOptions] = None, **kw):
    """A whole pre-norm transformer block — attention residual → FFN/MoE
    residual — as a single-period special case of :func:`sp_period` (the
    documented entry point for one block): ONE dataflow graph, optimized,
    executed in ONE ``shard_map``. The graph spans the attention-out →
    FFN-in seam, so pass 2 fuses RS → residual → LN → AG into one pipeline
    on every dense block and MoE routing flows through the same IR.

    ``params`` is the block param dict from ``models.transformer.init_block``
    (``norm1``/``mixer``/``norm2``/``ffn``). x: (B, S, d) sequence-sharded
    (or replicated with ``seq_sharded=False`` — the decode-style allreduce
    schedule). Options via ``opts`` / :class:`SPOptions` keywords. Returns
    (block output, aux loss)."""
    return sp_period(tpc, x, (params,), cfg, (kind,),
                     opts=_sp_opts(opts, kw))


def tp_applicable(cfg, kind: str, tp: int,
                  route_ring: Optional[int] = None) -> bool:
    """Explicit-backend shard_map path requires Q-head and feature
    divisibility (KV heads may replicate); otherwise the block stays on the
    `auto` path (DESIGN.md §5). ``route_ring`` is the expert-sharding ring
    (``tp`` on a flat mesh; ``tp_out`` on a hierarchical 2D mesh — pass
    ``TPContext.route_ring``)."""
    if kind in ("attn", "swa"):
        return cfg.num_heads % tp == 0 and cfg.norm == "rmsnorm"
    if kind == "ffn":
        return cfg.moe is None and cfg.d_ff > 0 and cfg.d_ff % tp == 0 \
            and cfg.norm == "rmsnorm"
    if kind == "moe":
        # integrated path requires true EP over the route ring: with
        # E < ring the owner mapping works (primitive-level tests) but
        # replicated expert weights turn their gradients into a full-size
        # all-reduce — measured regression, EXPERIMENTS.md §Perf cell 2.
        # Grouped EP (docs/topology.md) is the production fix: on a 2D
        # mesh the ring is only ``tp_out``, so E < tp archs qualify
        # whenever E % tp_out == 0 (expert grads psum over tp_in, the
        # fast intra-node links).
        ring = tp if route_ring is None else route_ring
        return cfg.moe is not None and cfg.norm == "rmsnorm" and \
            cfg.moe.num_experts % ring == 0
    return False
