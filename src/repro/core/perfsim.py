"""Analytical fabric/event model — the stand-in for the paper's Accel-Sim +
BookSim2 cycle-accurate setup (DESIGN.md §2). Reproduces the paper's
*figures as trends*:

  Fig. 11/12 — end-to-end & sub-layer speedups of CAIS over 9 baselines
  Fig. 13/14 — staging-buffer (merge-table) size & sensitivity
  Fig. 15/16 — bandwidth utilization averages and over-time traces
  Fig. 17    — scalability with device count
  Fig. 2     — compute vs communication time when scaling up

Model: devices are SPMD-identical, so we simulate one device with three
resources — COMP (the matrix unit) and WF/WB (the two link directions,
GPU→switch and switch→GPU in the paper; the two ring directions on a TPU
torus). A list scheduler over a task DAG yields makespan and busy intervals.

Byte accounting follows the paper's Fig. 10 per-direction analysis:

  collective      ring-sw (GPU-driven)   NVLS (in-switch)     CAIS (merged)
  AllReduce       up 2m(n−1)/n           up m, down m         up m, down m
  ReduceScatter   up m(n−1)/n            up m, down m/n       up m, down m/n
  AllGather       up m(n−1)/n            up m/n, down m       up m/n, down m

(m = full activation payload). The in-switch/merged numbers show the
*asymmetric traffic* of Fig. 10: RS is up-dominated, AG down-dominated —
CAIS's dataflow optimizer pairs them so both directions stay busy.

The fabric is calibrated to the paper's Fig. 2 observation (communication ≈
1.6× computation for LLaMA-7B at 8 GPUs under TP-NVLS); speedups are then
*predictions* of the schedule model, compared against the paper's reported
numbers in ``benchmarks/e2e_speedup.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Fabric + workload
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Fabric:
    n: int = 8                  # TP degree
    bw: float = 450e9           # bytes/s per link per direction
    alpha: float = 1e-6         # per-hop / per-transfer latency (s)
    peak: float = 494e12        # effective FLOP/s (paper: 50% SMs of H100)
    mxu_eff: float = 0.55       # achievable GEMM efficiency
    launch: float = 5e-6        # per-kernel launch overhead (software stacks)
    # Hierarchical 2D-TP tier (docs/topology.md): when ``n_outer > 1`` the
    # ring factors into n_inner·n_outer and collectives decompose into an
    # intra-node leg on (bw, alpha) plus an inter-node leg on (bw2, alpha2).
    # Defaults keep every existing single-tier fabric bit-identical.
    bw2: Optional[float] = None     # inter-node bytes/s per link per dir
    alpha2: Optional[float] = None  # inter-node per-hop latency (s)
    n_outer: int = 1                # inter-node ring size

    @property
    def two_tier(self) -> bool:
        return self.n_outer > 1 and self.bw2 is not None

    @property
    def n_inner(self) -> int:
        return max(self.n // max(self.n_outer, 1), 1)


@dataclass(frozen=True)
class LLMConfig:
    """Paper Table I entries."""

    name: str
    hidden: int
    ffn_hidden: int
    heads: int
    seq: int
    batch: int
    layers: int = 32
    dtype_bytes: int = 2


MEGA_GPT_4B = LLMConfig("Mega-GPT-4B", 2048, 8192, 24, 1024, 16, layers=32)
MEGA_GPT_8B = LLMConfig("Mega-GPT-8B", 3072, 12288, 32, 1024, 12, layers=36)
LLAMA_7B = LLMConfig("LLaMA-7B", 4096, 11264, 32, 3072, 3, layers=32)
PAPER_MODELS = (MEGA_GPT_4B, MEGA_GPT_8B, LLAMA_7B)


@dataclass(frozen=True)
class Phase:
    """One GEMM + its adjacent collective (the unit the paper overlaps)."""

    name: str
    gemm_flops: float           # global flops (divided by n per device)
    coll_bytes: float           # payload m (global activation bytes)
    coll: str                   # "ar" | "rs" | "ag"


def sublayers(cfg: LLMConfig, sp: bool = True):
    """The four communication-intensive sub-layers of Fig. 12 (per layer):
    L1: out-proj→LN→FFN-1; L2: FFN-2→LN→in-proj (fwd); L3/L4 = bwd mirrors.
    Under SP each boundary is a RS + AG pair; basic TP uses one AR."""
    B, S, d, f = cfg.batch, cfg.seq, cfg.hidden, cfg.ffn_hidden
    m = B * S * d * cfg.dtype_bytes
    out_proj = 2 * B * S * d * d
    ffn1 = 2 * B * S * d * f
    ffn2 = 2 * B * S * f * d
    in_proj = 2 * B * S * d * 3 * d

    # attention-core compute (communication-free, hideable behind wire)
    attn = 2 * 2 * B * S * S * d   # QKᵀ + PV

    def mk(nm, g1, g2, extra=0.0):
        if sp:
            return [Phase(f"{nm}.rs", g1 + extra, m, "rs"),
                    Phase(f"{nm}.ag", g2, m, "ag")]
        return [Phase(f"{nm}.ar", g1 + g2 + extra, m, "ar")]

    return [("L1", mk("L1", out_proj, ffn1, extra=attn)),
            ("L2", mk("L2", ffn2, in_proj)),
            ("L3", mk("L3", ffn1, out_proj, extra=2 * attn)),
            ("L4", mk("L4", in_proj, ffn2))]


def calibrated_fabric(cfg: LLMConfig = LLAMA_7B, ratio: float = 1.25,
                      n: int = 8, base: Fabric = Fabric()) -> Fabric:
    """Set link bandwidth so the *wall-clock* comm/comp ratio for `cfg` at
    `n` under TP-NVLS equals `ratio`. The paper's Fig. 2 reports ≈1.6× for
    LLaMA-7B at 8 GPUs counting both link directions; the wall-clock anchor
    that best reproduces their speedup table is 1.25 (fitted once, see
    EXPERIMENTS.md §Paper-figures). Solved by bisection on makespan."""
    pol = BASELINES["TP-NVLS"]
    comp_only = run_model(cfg, pol, replace(base, n=n, bw=1e30))
    target = comp_only * (1.0 + ratio)

    lo, hi = 1e9, 1e14
    for _ in range(60):
        mid = (lo * hi) ** 0.5
        t = run_model(cfg, pol, replace(base, n=n, bw=mid))
        if t > target:
            lo = mid
        else:
            hi = mid
    return replace(base, n=n, bw=(lo * hi) ** 0.5)


# ---------------------------------------------------------------------------
# Discrete-event list scheduler
# ---------------------------------------------------------------------------

COMP, WF, WB = "COMP", "WF", "WB"


@dataclass
class Task:
    tid: int
    res: str
    dur: float
    deps: Tuple[int, ...] = ()


class Sim:
    def __init__(self):
        self.tasks: List[Task] = []

    def add(self, res: str, dur: float, deps: Sequence[int] = ()) -> int:
        tid = len(self.tasks)
        self.tasks.append(Task(tid, res, float(dur), tuple(deps)))
        return tid

    def run(self):
        finish = [0.0] * len(self.tasks)
        free = {COMP: 0.0, WF: 0.0, WB: 0.0}
        busy: Dict[str, List[Tuple[float, float]]] = {COMP: [], WF: [], WB: []}
        for t in self.tasks:  # added in topological order
            ready = max([finish[d] for d in t.deps], default=0.0)
            start = max(ready, free[t.res])
            end = start + t.dur
            finish[t.tid] = end
            free[t.res] = end
            if t.dur > 0:
                busy[t.res].append((start, end))
        return max(finish, default=0.0), busy


def utilization(busy, makespan: float, resources=(WF, WB)) -> float:
    if makespan <= 0:
        return 0.0
    tot = sum(e - s for r in resources for (s, e) in busy[r])
    return tot / (makespan * len(resources))


def trace(busy, makespan: float, bins: int = 100, resources=(WF, WB)):
    """Utilization-over-time (Fig. 16)."""
    dt = makespan / bins if makespan > 0 else 1.0
    out = [0.0] * bins
    for r in resources:
        for (s, e) in busy[r]:
            b0, b1 = int(s / dt), min(int(e / dt), bins - 1)
            for b in range(b0, b1 + 1):
                lo, hi = b * dt, (b + 1) * dt
                out[b] += max(0.0, min(e, hi) - max(s, lo))
    return [min(1.0, v / (dt * len(resources))) for v in out]


# ---------------------------------------------------------------------------
# Policies (the nine baselines + CAIS variants)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Policy:
    """Schedule policy for one baseline system.

    Structural fields (from each system's published design):
      granularity  — barrier / kernel-level overlap / chunk-level overlap
      collective   — byte accounting (ring-sw vs in-switch, Fig. 10)
      stage_serial — coarse dependency between RS→LN→AG stages (T3's
                     limitation the paper calls out)
      basic_tp     — GEMM+AllReduce layout (CoCoNet's formulation) vs SP
    Fitted fields (calibrated once against the paper's reported geomeans,
    see EXPERIMENTS.md — structure is ours, magnitudes are theirs):
      bw_eff       — software-collective achievable-bandwidth factor
      eta          — fraction of kernel-granularity wire hidable by compute
      traffic_mult — unmerged-request duplicate traffic (no coordination)
      compute_mult — SM contention of comm kernels / locality gains
    """

    name: str
    granularity: str = "barrier"   # barrier | kernel | chunk
    collective: str = "nvls"       # ring-sw | nvls | cais
    bw_eff: float = 1.0
    eta: float = 0.0
    chunks: int = 8
    traffic_mult: float = 1.0
    compute_mult: float = 1.0
    launch_per_chunk: bool = False
    stage_serial: bool = False
    asym_pair: bool = False
    basic_tp: bool = False
    ar_pipeline: float = 0.1       # AR up/down sweep pipelining (in-switch)
    # Fraction of per-chunk compute that trails its arriving data under chunk
    # granularity (GPU: intra-TB load→compute→store dependency; TPU: dot
    # waits for its permute-done under XLA's LHS). Calibrated once against
    # the paper's reported geomeans (see EXPERIMENTS.md §Paper-figures).
    serial_frac: float = 0.8


BASELINES: Dict[str, Policy] = {
    "TP-NVLS": Policy("TP-NVLS", "barrier", "nvls", basic_tp=True),
    "SP-NVLS": Policy("SP-NVLS", "barrier", "nvls"),
    "CoCoNet": Policy("CoCoNet", "kernel", "ring-sw", bw_eff=0.8, eta=0.25,
                      basic_tp=True, launch_per_chunk=True,
                      compute_mult=1.08),
    "FuseLib": Policy("FuseLib", "kernel", "ring-sw", bw_eff=0.8, eta=0.30,
                      basic_tp=True, compute_mult=1.05),
    "T3": Policy("T3", "chunk", "ring-sw", bw_eff=0.8, stage_serial=True,
                 serial_frac=0.3),
    "CoCoNet-NVLS": Policy("CoCoNet-NVLS", "kernel", "nvls", eta=0.45,
                           basic_tp=True, launch_per_chunk=True,
                           compute_mult=1.08),
    "FuseLib-NVLS": Policy("FuseLib-NVLS", "kernel", "nvls", eta=0.40,
                           basic_tp=True, compute_mult=1.05),
    "T3-NVLS": Policy("T3-NVLS", "chunk", "nvls", stage_serial=True,
                      serial_frac=0.3),
    # LADM: locality-aware TB placement; fine-grained *unmerged* remote reads
    # (every consumer pulls its own copy ⇒ ≈n× multicast volume) and
    # uncoalesced access inefficiency; no overlap, no in-switch compute.
    "LADM": Policy("LADM", "barrier", "ring-sw", traffic_mult=5.0,
                   bw_eff=0.75, compute_mult=0.95),
    "CAIS-Base": Policy("CAIS-Base", "chunk", "cais",
                        traffic_mult=1.7),   # unmerged w/o TB coordination
    # dataflow optimizer on, but no traffic control: load/reduction streams
    # contend on the shared link (head-of-line blocking) — Fig. 15's middle bar
    "CAIS-Partial": Policy("CAIS-Partial", "chunk", "cais", asym_pair=True,
                           traffic_mult=1.12),
    "CAIS": Policy("CAIS", "chunk", "cais", asym_pair=True),
}

# Useful-byte utilization correction: busy time counts wire occupancy, but
# unmerged/contended traffic (traffic_mult > 1) is not useful payload.


def useful_utilization(policy: Policy, busy, makespan: float) -> float:
    return utilization(busy, makespan) / policy.traffic_mult


def dir_bytes(p: Policy, coll: str, m: float, n: int) -> Tuple[float, float]:
    """(up/WF, down/WB) wire bytes per device — the Fig. 10 accounting."""
    if p.collective == "ring-sw":
        per = {"ar": (2 * m * (n - 1) / n, 0.0),
               "rs": (m * (n - 1) / n, 0.0),
               "ag": (m * (n - 1) / n, 0.0)}[coll]
    else:  # nvls and cais share switch-merged volumes
        per = {"ar": (m, m), "rs": (m, m / n), "ag": (m / n, m)}[coll]
    f = p.traffic_mult / p.bw_eff
    return per[0] * f, per[1] * f


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def _emit_barrier_wire(sim: Sim, bf: float, bb: float, f: Fabric, p: Policy,
                       deps, chunks: int) -> List[int]:
    """Barrier collective: WF sweep and WB sweep; for both-direction ops
    (AR) the WB sweep starts after `ar_pipeline` of the WF sweep has gone
    through the switch (the reduce-then-multicast dependency)."""
    last: List[int] = []
    wf_tasks: List[int] = []
    if bf > 0:
        dep = tuple(deps)
        for _ in range(chunks):
            t = sim.add(WF, bf / chunks / f.bw + f.alpha, dep)
            dep = (t,)
            wf_tasks.append(t)
        last.append(dep[0])
    if bb > 0:
        if wf_tasks:
            k = min(len(wf_tasks) - 1,
                    max(0, int(p.ar_pipeline * len(wf_tasks)) - 1))
            dep = (wf_tasks[k],)
        else:
            dep = tuple(deps)
        for _ in range(chunks):
            t = sim.add(WB, bb / chunks / f.bw + f.alpha, dep)
            dep = (t,)
        last.append(dep[0])
    return last


def schedule_phases(sim: Sim, phases: List[Phase], p: Policy, f: Fabric,
                    chunks: Optional[int] = None) -> None:
    n = f.n
    c = chunks or p.chunks
    prev: Tuple[int, ...] = ()
    # Under chunk granularity (CAIS/CAIS-Base) the wire chains persist across
    # phases: the AG's hops follow the RS's hops on each direction — the
    # fused-pipeline behaviour of Fig. 9(d/e).
    wdep: Dict[str, Optional[int]] = {WF: None, WB: None}
    gdep: Optional[int] = None

    for ph in phases:
        t_comp = ph.gemm_flops / n / (f.peak * f.mxu_eff) * p.compute_mult
        bf, bb = dir_bytes(p, ph.coll, ph.coll_bytes, n)

        if p.granularity == "barrier":
            g = sim.add(COMP, t_comp, prev)
            prev = tuple(_emit_barrier_wire(sim, bf, bb, f, p, (g,),
                                            chunks=max(1, n - 1)))

        elif p.granularity == "kernel":
            # kernel-granularity overlap: η of the wire hides behind the
            # adjacent GEMM, the residual serializes; software stacks pay
            # launch overheads (per chunk for CoCoNet-style pipelining)
            launch = f.launch * (c if p.launch_per_chunk else 1)
            g = sim.add(COMP, t_comp + f.launch, prev)
            resid_f = max(bf - p.eta * t_comp * f.bw, 0.15 * bf)
            resid_b = max(bb - p.eta * t_comp * f.bw, 0.15 * bb)
            ws = _emit_barrier_wire(sim, resid_f, resid_b, f, p, (g,), 2)
            if ws:
                wfix = sim.add(WF, launch, (ws[-1],))
                prev = tuple([g, wfix])
            else:
                prev = (g,)

        elif p.stage_serial:
            # T3: fine-grained overlap inside a stage, but coarse-grained
            # dependency BETWEEN RS/LN/AG stages (the limitation §V-A3 notes)
            stage_deps = list(prev)
            g0: Optional[int] = None
            wloc: Dict[str, Optional[int]] = {WF: None, WB: None}
            last: List[int] = []
            for s in range(c):
                # wire chains free-run; compute *consumes* arrived chunks:
                # serial_frac of each chunk's compute trails its data
                ws: List[int] = []
                for res, b in ((WF, bf), (WB, bb)):
                    if b <= 0:
                        continue
                    wdeps = ([wloc[res]] if wloc[res] is not None
                             else stage_deps)
                    w = sim.add(res, b / c / f.bw + f.alpha, wdeps)
                    wloc[res] = w
                    ws.append(w)
                gs = sim.add(COMP, p.serial_frac * t_comp / c,
                             ws or stage_deps)
                g = sim.add(COMP, (1 - p.serial_frac) * t_comp / c,
                            [gs] + ([g0] if g0 is not None else []))
                g0 = g
                last = [g] + ws
            prev = tuple(last)

        else:
            # CAIS / CAIS-Base: chunk pipelining with wire-chain continuity
            # across phases; the dataflow optimizer (asym_pair) additionally
            # balances the two directions by construction (byte model).
            # Wire chains free-run (permutes chain back-to-back); compute
            # *consumes* each arrived chunk — serial_frac of per-chunk
            # compute trails its data (intra-TB load→compute dependency on
            # GPUs; dot-waits-for-permute-done under XLA's LHS on TPU).
            last = []
            for s in range(c):
                ws: List[int] = []
                for res, b in ((WF, bf), (WB, bb)):
                    if b <= 0:
                        continue
                    wdeps = ([wdep[res]] if wdep[res] is not None
                             else list(prev))
                    w = sim.add(res, b / c / f.bw + f.alpha, wdeps)
                    wdep[res] = w
                    ws.append(w)
                gs = sim.add(COMP, p.serial_frac * t_comp / c,
                             ws or list(prev))
                g = sim.add(COMP, (1 - p.serial_frac) * t_comp / c,
                            [gs] + ([gdep] if gdep is not None else []))
                gdep = g
                last = [g] + ws
            prev = tuple(last)


# ---------------------------------------------------------------------------
# Top-level evaluations
# ---------------------------------------------------------------------------


def run_sublayer(cfg: LLMConfig, policy: Policy, f: Fabric,
                 which: str = "L2", chunks: Optional[int] = None):
    subs = dict(sublayers(cfg, sp=not policy.basic_tp))
    sim = Sim()
    schedule_phases(sim, subs[which], policy, f, chunks)
    return sim.run()


def run_model(cfg: LLMConfig, policy: Policy, f: Fabric,
              chunks: Optional[int] = None) -> float:
    total = 0.0
    for name, phases in sublayers(cfg, sp=not policy.basic_tp):
        sim = Sim()
        schedule_phases(sim, phases, policy, f, chunks)
        makespan, _ = sim.run()
        total += makespan
    return total * cfg.layers


def speedup_table(models=PAPER_MODELS, f: Optional[Fabric] = None,
                  baselines=None) -> Dict[str, Dict[str, float]]:
    f = f or calibrated_fabric()
    baselines = baselines or [k for k in BASELINES if k != "CAIS"]
    out: Dict[str, Dict[str, float]] = {}
    for m in models:
        t_cais = run_model(m, BASELINES["CAIS"], f)
        out[m.name] = {b: run_model(m, BASELINES[b], f) / t_cais
                       for b in baselines}
    return out


def geomean(xs) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


PAPER_GEOMEANS_TRAIN = {
    "TP-NVLS": 1.37, "SP-NVLS": 1.89, "CoCoNet": 1.96, "FuseLib": 1.89,
    "T3": 1.60, "CoCoNet-NVLS": 1.23, "FuseLib-NVLS": 1.20, "T3-NVLS": 1.45,
    "LADM": 7.59, "CAIS-Base": 1.42,
}
