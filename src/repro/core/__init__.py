"""CAIS-on-TPU core: compute-aware collective-fused TP schedules (the
paper's primary contribution), the registry-dispatched CollectiveBackend
API, the chunk-coordination scheduler, the graph-level dataflow optimizer,
and the calibrated fabric model."""
from repro.core.backends import (
    CollectiveBackend,
    available_backends,
    get_backend,
    register_backend,
    unregister_backend,
)
from repro.core.primitives import (
    CAISConfig,
    ag_gemm,
    ag_gemm_multi,
    barrier_ag_gemm,
    barrier_gemm_ar,
    barrier_gemm_rs,
    fused_rs_ln_ag,
    gemm_ar,
    gemm_rs,
    overlap_asymmetric,
    ring_all_gather,
)

__all__ = [
    "CAISConfig", "CollectiveBackend", "ag_gemm", "ag_gemm_multi",
    "available_backends", "barrier_ag_gemm", "barrier_gemm_ar",
    "barrier_gemm_rs", "fused_rs_ln_ag", "gemm_ar", "gemm_rs", "get_backend",
    "overlap_asymmetric", "register_backend", "ring_all_gather",
    "unregister_backend",
]
