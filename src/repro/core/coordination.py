"""Chunk scheduling — the TPU analogue of the paper's merging-aware TB
coordination (§III-B).

On SPMD TPU the paper's *temporal alignment* problem is solved structurally:
every chip runs the same program, so chunk k's permute is issued at the same
program point everywhere (the 35 µs request skew of independently-scheduled
TBs does not exist). What remains is the *resource* side of the same
trade-off: the per-step staging buffer (our merge-table analogue) holds
``payload / num_chunks`` bytes in flight, and the hop latency α plays the
role of the merge-window — chunks too small make latency dominate (the
analogue of early-arriving requests being evicted before their peers show
up), chunks too big serialize compute behind communication.

:func:`plan` picks ``num_chunks`` from the α-β model under a staging-bytes
budget; :func:`schedule_metrics` evaluates any chunking (the Fig. 13/14
sensitivity sweeps call it directly); :func:`plan_microbatches` applies the
same latency-floor reasoning one level up, to how many independent
microbatch chains a period graph should split into (``tp.sp_period``'s
``num_microbatches="auto"``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.hw import HWSpec, V5E


@dataclass(frozen=True)
class SchedulePlan:
    num_chunks: int
    staging_bytes: int          # per-step in-flight bytes (merge-table size)
    step_time: float            # per ring-step wall time (s)
    total_comm: float           # full ring traversal (s)
    latency_fraction: float     # α / per-chunk time — merge-window pressure
    overlap_efficiency: float   # fraction of wire time hideable behind compute
    # the staging-bytes budget forced num_chunks past the latency cap
    # (max_chunks): the budget wins, but callers can see it happened instead
    # of silently getting c > max_chunks
    over_cap: bool = False


def schedule_metrics(payload_bytes: float, ring: int, num_chunks: int,
                     compute_time: float = 0.0,
                     bidirectional: bool = True,
                     hw: HWSpec = V5E) -> SchedulePlan:
    """Evaluate one chunking choice.

    payload_bytes: full (global) tensor bytes moved by the collective.
    ring: TP axis size. compute_time: the GEMM time available to hide wire
    time behind (0 = bare collective)."""
    c = max(1, num_chunks)
    dirs = 2 if bidirectional else 1
    shard = payload_bytes / ring                  # bytes per device
    chunk = shard / c                             # bytes per micro-chunk
    wire_per_dir = chunk / dirs / hw.ici_bw
    step_time = hw.hop_latency + wire_per_dir
    steps = (ring - 1) * c
    total = steps * step_time
    per_chunk = hw.hop_latency + wire_per_dir
    lat_frac = hw.hop_latency / per_chunk
    if compute_time > 0:
        hidden = min(total, compute_time)
        eff = hidden / total if total > 0 else 1.0
    else:
        eff = 0.0
    return SchedulePlan(
        num_chunks=c,
        staging_bytes=int(chunk),
        step_time=step_time,
        total_comm=total,
        latency_fraction=lat_frac,
        overlap_efficiency=eff,
    )


def plan(payload_bytes: float, ring: int, *, compute_time: float = 0.0,
         staging_budget: int = 4 * 1024**2, max_latency_fraction: float = 0.25,
         bidirectional: bool = True, max_chunks: int = 64,
         hw: HWSpec = V5E) -> SchedulePlan:
    """Pick num_chunks: the largest chunking (finest overlap) whose per-chunk
    latency fraction stays below ``max_latency_fraction``, subject to the
    staging buffer fitting ``staging_budget``. Mirrors the paper's finding
    that coordination lets a small merge table (40 KB/port) suffice.

    The latency cap ``max_chunks`` bounds the chunk count from above; the
    staging budget bounds it from below (``c >= shard / budget``). When the
    two conflict the budget wins (staging bytes are a hard resource), and the
    returned plan flags ``over_cap=True`` instead of silently exceeding the
    cap. With ``compute_time > 0`` the planner additionally prefers the
    finest chunking whose full wire time still fits UNDER the available
    compute time — ``total_comm(c) = (ring-1)·(c·α + shard/(dirs·bw))`` grows
    with c, so past the point where wire time stops hiding behind compute,
    extra chunks only add exposed hop latency."""
    shard = payload_bytes / ring
    # latency bound: chunk >= α·β·(1/maxfrac - 1)
    dirs = 2 if bidirectional else 1
    min_chunk = hw.hop_latency * hw.ici_bw * dirs * \
        (1.0 / max_latency_fraction - 1.0)
    c_latency = max(1, int(shard / max(min_chunk, 1.0)))
    # staging bound: chunk <= budget  =>  c >= shard / budget
    c_staging = max(1, math.ceil(shard / staging_budget))
    c_hi = max(c_staging, min(c_latency, max_chunks))
    c = c_hi
    if compute_time > 0 and ring > 1 and hw.hop_latency > 0:
        # finest c whose total wire time fits under compute_time:
        # (ring-1)·(c·α + shard/(dirs·bw)) <= compute_time
        slack = compute_time / (ring - 1) - shard / (dirs * hw.ici_bw)
        c_fit = int(slack / hw.hop_latency) if slack > 0 else 0
        c = min(c_hi, max(c_staging, c_fit))
    p = schedule_metrics(payload_bytes, ring, c, compute_time,
                         bidirectional, hw)
    return dataclasses.replace(p, over_cap=c_staging > max_chunks)


def plan_microbatches(batch: int, payload_bytes: float, ring: int, *,
                      max_microbatches: int = 4,
                      max_latency_fraction: float = 0.25,
                      bidirectional: bool = True,
                      hw: HWSpec = V5E) -> int:
    """How many independent microbatch chains should a period graph split
    into (``tp.sp_period``'s ``num_microbatches="auto"``)?

    Splitting multiplies the independent gemm_rs/ag_gemm pairs pass 3 can
    co-schedule (``overlap_asym``) but divides every collective's payload by
    the same factor, pushing chunks toward the hop-latency floor — the same
    merge-window trade-off :func:`plan` resolves one level down. Accept the
    largest power-of-two split (≤ ``max_microbatches``) that divides
    ``batch`` and whose per-microbatch α-β plan still carries ≥2 chunks
    above the latency bound (room left to pipeline within each chain).

    ``payload_bytes`` is the full-batch payload of the period's largest
    collective (the gathered activation); ``batch`` is the per-device batch
    the split has to divide."""
    if ring <= 1 or batch <= 1:
        return 1
    mb = 1
    cand = 2
    while cand <= min(max_microbatches, batch):
        if batch % cand:
            break
        p = plan(payload_bytes / cand, ring,
                 max_latency_fraction=max_latency_fraction,
                 bidirectional=bidirectional, hw=hw)
        if p.num_chunks < 2:
            break
        mb = cand
        cand *= 2
    return mb
