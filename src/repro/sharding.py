"""Mesh / sharding conventions for the whole framework.

Axis naming (DESIGN.md §6):
  * ``pod``   — cross-pod data parallelism (only on the multi-pod mesh)
  * ``data``  — in-pod data parallelism (+ context parallelism for batch-1)
  * ``model`` — tensor / sequence / expert parallelism (high-bandwidth ICI)

Model code never touches a mesh directly: it calls :func:`shard` with a
logical :class:`jax.sharding.PartitionSpec`. When no mesh is active (CPU smoke
tests, single device) the call is a no-op, so the same model runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"

# Hierarchical (2D) tensor parallelism: the model axis factors into a fast
# intra-node ring × a slow inter-node axis (docs/topology.md). A shard index
# along the composite axis is ``i_in * tp_out + i_out`` — ``tp_in`` major,
# matching jax's tuple-PartitionSpec semantics for ``("tp_in", "tp_out")``.
TP_IN_AXIS = "tp_in"
TP_OUT_AXIS = "tp_out"
TP_AXES_2D = (TP_IN_AXIS, TP_OUT_AXIS)


def make_mesh(shape, axes) -> Mesh:
    """Version-portable ``jax.make_mesh`` with Auto axis types when the
    running jax supports them (older releases have neither ``AxisType`` nor
    the ``axis_types`` parameter)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_tp_mesh(tp_in: int, tp_out: int, dp: int = 1) -> Mesh:
    """A hierarchical-TP mesh: ``data × tp_in × tp_out`` with the model axis
    factored into the fast intra-node ring (``tp_in``) × the slow inter-node
    axis (``tp_out``). ``tp_out == 1`` still builds the 2D mesh (useful for
    degenerate-factorization parity tests); callers wanting the flat ring use
    ``make_mesh((dp, tp), ("data", "model"))`` as before."""
    return make_mesh((dp, tp_in, tp_out), (DATA_AXIS,) + TP_AXES_2D)


def tp_axes(mesh: Optional[Mesh]):
    """The TP axis entry for PartitionSpecs / collective calls on ``mesh``:
    the flat ``"model"`` string on 1D meshes, the composite
    ``("tp_in", "tp_out")`` tuple on hierarchical meshes (tp_in major)."""
    if mesh is not None and TP_IN_AXIS in mesh.axis_names \
            and TP_OUT_AXIS in mesh.axis_names:
        return TP_AXES_2D
    return MODEL_AXIS


def shard_map_axis_size(axis) -> int:
    """Size of a named mesh axis (or product over a composite-axis tuple)
    from *inside* shard_map, version-portable: newer jax has
    ``lax.axis_size``; older releases constant-fold ``psum(1, axis)`` to the
    same value."""
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= shard_map_axis_size(a)
        return n
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map_axis_index(axis):
    """Flattened device index along ``axis`` from inside shard_map. For a
    composite tuple the first member is major (index = i0·n1·… + i1·… + …),
    consistent with jax's tuple-PartitionSpec shard order."""
    if isinstance(axis, (tuple, list)):
        idx = jax.lax.axis_index(axis[0])
        for a in axis[1:]:
            idx = idx * shard_map_axis_size(a) + jax.lax.axis_index(a)
        return idx
    return jax.lax.axis_index(axis)


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``: newer jax exposes ``jax.shard_map``
    (with ``check_vma``); older releases ship it under ``jax.experimental``
    (where the flag is ``check_rep``). All repo code routes through here."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)

# Batch dims shard over every data-parallel axis present on the mesh.
BATCH_AXES = (POD_AXIS, DATA_AXIS)

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _filter_spec(mesh: Mesh, spec: P) -> P:
    """Drop axis names that the active mesh does not have (e.g. ``pod`` on the
    single-pod mesh) so one logical spec serves every mesh."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def shard(x, *spec_entries):
    """``with_sharding_constraint`` against the active mesh; no-op without one.

    ``shard(x, ("pod","data"), None, "model")`` pins batch to the DP axes and
    the last dim to the TP axis.
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = _filter_spec(mesh, P(*spec_entries))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *spec_entries) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(mesh, P(*spec_entries)))


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> NamedSharding:
    """(batch, seq, ...) sharding: batch over DP axes, rest replicated."""
    return named_sharding(mesh, BATCH_AXES, *([None] * extra_dims))


def axis_size(mesh: Optional[Mesh], name: str) -> int:
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def dp_size(mesh: Optional[Mesh]) -> int:
    return axis_size(mesh, POD_AXIS) * axis_size(mesh, DATA_AXIS)


def tp_size(mesh: Optional[Mesh]) -> int:
    """Total TP degree — the flat model axis, or the product of the 2D
    factors on a hierarchical mesh (the two are mutually exclusive)."""
    return axis_size(mesh, MODEL_AXIS) * \
        axis_size(mesh, TP_IN_AXIS) * axis_size(mesh, TP_OUT_AXIS)
