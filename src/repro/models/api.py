"""Model factory: ArchConfig -> model object with the uniform API

    init(key) -> params
    loss(params, batch) -> scalar                       (train_step)
    prefill(params, batch[, s_max]) -> (logits, caches) (prefill step)
    decode_step(params, token, caches, idx) -> (logits, caches)
    init_cache(batch, s_max) -> caches
"""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM
from repro.models.vlm import VLM
from repro.runtime import Runtime


def build_model(cfg: ArchConfig, rt: Runtime = Runtime()):
    if cfg.is_enc_dec:
        return EncDecLM(cfg, rt)
    if cfg.num_prefix_tokens > 0:
        return VLM(cfg, rt)
    return LM(cfg, rt)
