"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked dual form: quadratic attention-like compute
inside chunks + a linear recurrence across chunk states (a ``lax.scan``).
Decode is the O(1)-per-token stateful step. The recurrence is sequence-local
(no TP collective) — CAIS applies to the in/out projections only
(DESIGN.md §5, arch-applicability).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

NEG_INF = -1e30


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nheads, conv_ch


def init_ssm(key, cfg: ArchConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    # in_proj -> [z (d_inner), xBC (conv_ch), dt (nheads)]
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * s.n_groups * s.d_state
                                   + nheads), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch),
                             in_axis_size=s.conv_width, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, d), in_axis_size=d_inner,
                            dtype=dtype),
    }


def _segsum(x):
    """x: (..., l) -> (..., l, l) with S[i,j] = sum_{k=j+1..i} x[k] (j<=i)."""
    cs = jnp.cumsum(x, -1)
    S = cs[..., :, None] - cs[..., None, :]
    l = x.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, S, NEG_INF)


def _ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD. x: (b,s,h,p); dt: (b,s,h) post-softplus; A: (h,) negative;
    B,C: (b,s,g,n). Returns (y (b,s,h,p), h_final (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    c, l = s // chunk, chunk
    hg = h // g  # heads per group

    def cshape(t):  # (b,s,...) -> (b,c,l,...)
        return t.reshape(b, c, l, *t.shape[2:])

    xc, dtc, Bc, Cc = map(cshape, (x, dt, B, C))
    # decay math in f32 (exp/cumsum are precision-sensitive under bf16)
    dA = dtc.astype(jnp.float32) * A.astype(jnp.float32)[None, None, None, :]
    dA_cs = jnp.cumsum(dA, axis=2)                        # (b,c,l,h)

    # intra-chunk (the "attention-like" quadratic term)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (b,c,h,l,l)
    CB = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc,
                    preferred_element_type=jnp.float32)   # (b,c,g,l,m)
    CB = jnp.repeat(CB, hg, axis=2)                       # (b,c,h,l,m)
    gate = (CB * L).astype(x.dtype)
    xdt = xc * dtc.astype(x.dtype)[..., None]             # (b,c,l,h,p)
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", gate, xdt)

    # chunk states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)   # (b,c,l,h)
    Bh = jnp.repeat(Bc, hg, axis=3).reshape(b, c, l, g, hg, n)
    Bh = Bh.reshape(b, c, l, h, n)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh,
                        decay_states.astype(x.dtype), xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (b,c,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)

    def step(hprev, inp):
        dec, st = inp  # dec (b,h), st (b,h,p,n)
        hnew = hprev * dec[..., None, None].astype(x.dtype) + st
        return hnew, hprev

    hT, hprevs = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)              # (b,c,h,p,n)

    # inter-chunk contribution
    Ch = jnp.repeat(Cc, hg, axis=3).reshape(b, c, l, h, n)
    state_decay = jnp.exp(dA_cs).astype(x.dtype)          # (b,c,l,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, hprevs, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, hT


def _split_in(proj, cfg: ArchConfig):
    s = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, bias):
    """Depthwise causal conv. xBC: (b,s,ch); w: (width,ch)."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + bias[None, None, :]


def _gated_norm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    out = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def ssm_forward(params, x, cfg: ArchConfig, h0=None, conv0=None,
                return_state: bool = False):
    """x: (B,S,d). Returns y or (y, (ssm_state, conv_state))."""
    s = cfg.ssm
    bsz, S, _ = x.shape
    d_inner, nheads, conv_ch = _dims(cfg)
    dtype = x.dtype

    proj = x @ params["w_in"].astype(dtype)
    z, xBC, dt = _split_in(proj, cfg)
    if conv0 is not None:
        ext = jnp.concatenate([conv0.astype(dtype), xBC], axis=1)
        conv_out = _causal_conv(ext, params["conv_w"].astype(dtype),
                                params["conv_b"].astype(dtype))
        conv_out = conv_out[:, conv0.shape[1]:]
    else:
        conv_out = _causal_conv(xBC, params["conv_w"].astype(dtype),
                                params["conv_b"].astype(dtype))
    conv_out = jax.nn.silu(conv_out)
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                         axis=-1)
    xs = xs.reshape(bsz, S, nheads, s.head_dim)
    B = B.reshape(bsz, S, s.n_groups, s.d_state)
    C = C.reshape(bsz, S, s.n_groups, s.d_state)

    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    chunk = min(s.chunk_size, S)
    while S % chunk:
        chunk //= 2
    if h0 is not None:
        h0 = h0.astype(dtype)
    y, hT = _ssd_chunked(xs, dt, A, B, C, chunk, h0=h0)
    y = y + xs * params["D"].astype(dtype)[None, None, :, None]
    y = y.reshape(bsz, S, d_inner)
    y = _gated_norm(y, z, params["norm_scale"])
    out = y @ params["w_out"].astype(dtype)
    if return_state:
        conv_state = xBC[:, -(s.conv_width - 1):, :] if S >= s.conv_width - 1 \
            else jnp.pad(xBC, ((0, 0), (s.conv_width - 1 - S, 0), (0, 0)))
        return out, (hT, conv_state)
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nheads, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode(params, x, cache, cfg: ArchConfig):
    """One-token step. x: (B,1,d). Returns (y (B,1,d), new_cache)."""
    s = cfg.ssm
    bsz = x.shape[0]
    d_inner, nheads, conv_ch = _dims(cfg)
    dtype = x.dtype

    proj = x[:, 0] @ params["w_in"].astype(dtype)   # (B, ·)
    z, xBC, dt = _split_in(proj, cfg)

    window = jnp.concatenate([cache["conv"].astype(dtype), xBC[:, None]], 1)
    w = params["conv_w"].astype(dtype)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(dtype)
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                         axis=-1)
    xs = xs.reshape(bsz, nheads, s.head_dim)
    B = B.reshape(bsz, s.n_groups, s.d_state)
    C = C.reshape(bsz, s.n_groups, s.d_state)
    hg = nheads // s.n_groups
    Bh = jnp.repeat(B, hg, axis=1)   # (B, h, n)
    Ch = jnp.repeat(C, hg, axis=1)

    A = -jnp.exp(params["A_log"])
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    decay = jnp.exp(dt_f * A[None, :]).astype(dtype)                    # (B,h)

    dx = xs * dt_f.astype(dtype)[..., None]                             # (B,h,p)
    h_new = (cache["h"].astype(dtype) * decay[..., None, None]
             + dx[..., None] * Bh[:, :, None, :])                       # (B,h,p,n)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    y = y + xs * params["D"].astype(dtype)[None, :, None]
    y = y.reshape(bsz, d_inner)
    y = _gated_norm(y, z, params["norm_scale"])
    out = (y @ params["w_out"].astype(dtype))[:, None]
    return out, {"h": h_new, "conv": new_conv}
