"""Shared layer primitives: norms, rotary embeddings, initializers, linear.

Everything is functional: ``init_*`` builds a param pytree, ``apply``-style
functions consume it. Params are stored in ``param_dtype`` and cast to the
runtime ``compute dtype`` at use sites.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None,
               dtype=jnp.float32):
    """Scaled-normal (truncated) fan-in init."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}  # gemma-style (1+scale)
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    raise ValueError(kind)


def apply_norm(kind: str, params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"].astype(jnp.float32))
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = ((xf - mu) * jax.lax.rsqrt(var + eps)
               * params["scale"].astype(jnp.float32)
               + params["bias"].astype(jnp.float32))
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name in ("gelu", "gelu_mlp"):
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def gated(name: str) -> bool:
    return name in ("silu", "gelu")


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
