"""FFN blocks: dense (gated / non-gated) MLP and capacity-bounded top-k MoE.

MoE follows the GShard/Mesh-TF formulation: tokens are reshaped into
``(groups, group_size)`` and dispatched to experts with a one-hot
capacity-bounded dispatch tensor. Experts shard over the ``model`` axis
(expert parallelism); groups shard over the data axes — XLA lowers the
dispatch/return einsums into all-to-alls on the production mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import activation, dense_init, gated

# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), in_axis_size=d_ff,
                             dtype=dtype),
    }
    if gated(act):
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_forward(params, x, act: str):
    dtype = x.dtype
    h = x @ params["w_up"].astype(dtype)
    if gated(act):
        h = activation(act, x @ params["w_gate"].astype(dtype)) * h
    else:
        h = activation(act, h)
    return h @ params["w_down"].astype(dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),  # f32 router
        "w_up": dense_init(ks[1], (E, d, f), in_axis_size=d, dtype=dtype),
        "w_down": dense_init(ks[2], (E, f, d), in_axis_size=f, dtype=dtype),
    }
    if gated(cfg.act):
        p["w_gate"] = dense_init(ks[3], (E, d, f), in_axis_size=d, dtype=dtype)
    if m.dense_residual_d_ff:
        p["dense"] = init_mlp(ks[4], d, m.dense_residual_d_ff, cfg.act, dtype)
    return p


def _top2_dispatch(probs: jnp.ndarray, capacity: int):
    """GShard top-2 dispatch. probs: (G, g, E) f32.

    Returns (dispatch (G,g,E,C) bool, combine (G,g,E,C) f32, aux_loss).

    Aux-loss cotangent convention (docs/training.md): ``aux`` is a
    first-class output — the graph path exposes it as the ``route`` node's
    third output and seeds its cotangent explicitly. Its gradient reaches
    the router logits only through the differentiable ``density_proxy``
    factor (mean router prob); the one-hot ``density`` factor is
    piecewise-constant in the logits, so ``jax.vjp`` of this function IS
    the Switch/GShard straight-through convention — no ``stop_gradient``
    needed, and the graph-built backward matches autodiff exactly."""
    G, g, E = probs.shape
    idx1 = jnp.argmax(probs, -1)
    mask1 = jax.nn.one_hot(idx1, E, dtype=probs.dtype)
    probs_wo1 = probs * (1.0 - mask1)
    idx2 = jnp.argmax(probs_wo1, -1)
    mask2 = jax.nn.one_hot(idx2, E, dtype=probs.dtype)

    # load-balancing auxiliary loss (Switch/GShard)
    density = jnp.mean(mask1, axis=1)              # (G, E) fraction routed
    density_proxy = jnp.mean(probs, axis=1)        # (G, E) mean router prob
    aux = jnp.mean(density * density_proxy) * (E * E)

    # capacity-bounded positions inside each expert buffer
    pos1 = jnp.cumsum(mask1, axis=1) * mask1 - mask1          # 0-based
    mask1 = mask1 * (pos1 < capacity)
    # second choice queues behind all first choices
    count1 = jnp.sum(mask1, axis=1, keepdims=True)
    pos2 = (jnp.cumsum(mask2, axis=1) * mask2 - mask2) + count1
    mask2 = mask2 * (pos2 < capacity)

    gate1 = jnp.sum(probs * mask1, -1)
    gate2 = jnp.sum(probs * mask2, -1)
    denom = jnp.maximum(gate1 + gate2, 1e-9)
    gate1, gate2 = gate1 / denom, gate2 / denom

    def onehot_pos(pos, mask):
        # (G,g,E) position -> (G,g,E,C) one-hot, zeroed where not routed
        oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=probs.dtype)
        return oh * mask[..., None]

    d1 = onehot_pos(pos1, mask1)
    d2 = onehot_pos(pos2, mask2)
    combine = gate1[..., None, None] * d1 + gate2[..., None, None] * d2
    dispatch = (d1 + d2) > 0.0
    return dispatch, combine, aux


def moe_forward(params, x, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    dtype = x.dtype
    E = m.num_experts
    g = min(m.group_size, B * S)
    while (B * S) % g:  # shrink until it divides (small/odd batches)
        g //= 2
    G = (B * S) // g
    xt = x.reshape(G, g, d)
    # tiny/ragged batches (e.g. decode S=1) can leave fewer groups than DP
    # shards — the group dim then stays replicated instead of carrying an
    # unsatisfiable sharding constraint
    g_ax = (sharding.BATCH_AXES
            if G % sharding.dp_size(sharding.current_mesh()) == 0 else None)
    xt = sharding.shard(xt, g_ax, None, None)

    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    capacity = max(1, int(g * m.top_k / E * m.capacity_factor))
    dispatch, combine, aux = _top2_dispatch(probs, capacity)

    # EP when experts divide the TP axis (arctic: 128/16); otherwise shard
    # the expert FFN's hidden dim instead (mixtral: 8 experts < 16 chips —
    # expert-TP avoids 2× padding waste). On a hierarchical 2D mesh the
    # rule is grouped EP (docs/topology.md): experts shard over the slow
    # ``tp_out`` axis only and replicate across ``tp_in``, whose share is
    # the expert hidden dim — so E < tp archs get true EP whenever
    # E % tp_out == 0. See launch/specs.py param rules.
    mesh = sharding.current_mesh()
    tp_ax = sharding.tp_axes(mesh)
    if isinstance(tp_ax, tuple):
        n_out = sharding.axis_size(mesh, sharding.TP_OUT_AXIS)
        ep = n_out > 1 and E % n_out == 0
        e_ax = sharding.TP_OUT_AXIS if ep else None
        f_ax = sharding.TP_IN_AXIS if ep else tp_ax
    else:
        tp = sharding.tp_size(mesh)
        ep = tp > 1 and E % tp == 0
        e_ax = sharding.MODEL_AXIS if ep else None
        f_ax = None if ep else sharding.MODEL_AXIS

    # dispatch: tokens -> expert buffers (E, G, C, d)
    einp = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dtype), xt)
    einp = sharding.shard(einp, e_ax, g_ax, None, None)

    h = jnp.einsum("egcd,edf->egcf", einp, params["w_up"].astype(dtype))
    h = sharding.shard(h, e_ax, g_ax, None, f_ax)
    if gated(cfg.act):
        gate = jnp.einsum("egcd,edf->egcf", einp,
                          params["w_gate"].astype(dtype))
        h = activation(cfg.act, gate) * h
    else:
        h = activation(cfg.act, h)
    eout = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(dtype))
    eout = sharding.shard(eout, e_ax, g_ax, None, None)

    out = jnp.einsum("gsec,egcd->gsd", combine.astype(dtype), eout)
    out = out.reshape(B, S, d)
    if m.dense_residual_d_ff:
        out = out + mlp_forward(params["dense"], x, cfg.act)
    return out, aux.astype(jnp.float32)


def ffn_forward(params, x, cfg: ArchConfig):
    """Unified FFN entry: returns (out, aux_loss)."""
    if cfg.moe is not None:
        return moe_forward(params, x, cfg)
    if cfg.d_ff == 0:  # attn-free mamba2 has no FFN block
        return jnp.zeros_like(x), jnp.float32(0.0)
    return mlp_forward(params, x, cfg.act), jnp.float32(0.0)


def init_ffn(key, cfg: ArchConfig, dtype=jnp.float32):
    if cfg.moe is not None:
        return init_moe(key, cfg, dtype)
    if cfg.d_ff == 0:
        return {}
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.act, dtype)
