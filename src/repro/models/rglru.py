"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t),   a_t = a^{c·r_t}

Training/prefill evaluates the linear recurrence with a log-depth
``associative_scan``; decode is the O(1) stateful step. Gates use
per-channel (diagonal) weights — a simplification of Griffin's
block-diagonal gates, noted in DESIGN.md. Sequence-local (no TP collective);
CAIS applies to the in/out projections (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

_C = 8.0  # Griffin's recurrence-gate temperature


def _lru_width(cfg: ArchConfig) -> int:
    return cfg.rglru.block_width or cfg.rglru.lru_width or cfg.d_model


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = _lru_width(cfg)
    cw = cfg.rglru.conv_width
    ks = jax.random.split(key, 4)
    return {
        "w_y": dense_init(ks[0], (d, w), dtype=dtype),       # gate branch
        "w_x": dense_init(ks[1], (d, w), dtype=dtype),       # recurrence branch
        "conv_w": dense_init(ks[2], (cw, w), in_axis_size=cw, dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a_w": jnp.zeros((w,), jnp.float32),
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_i_w": jnp.zeros((w,), jnp.float32),
        "gate_i_b": jnp.zeros((w,), jnp.float32),
        # a = sigmoid(Λ); init so a^c ≈ 0.9..0.999 over channels
        "Lambda": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
        "w_out": dense_init(ks[3], (w, d), in_axis_size=w, dtype=dtype),
    }


def _gates(params, u):
    """u: (..., w) conv output. Returns (log_a, gated_input) in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * params["gate_a_w"] + params["gate_a_b"])
    i = jax.nn.sigmoid(uf * params["gate_i_w"] + params["gate_i_b"])
    log_a = -_C * r * jax.nn.softplus(params["Lambda"])   # log(a^{c·r}) ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * uf


def _causal_conv(x, w, b, x0=None):
    width = w.shape[0]
    if x0 is not None:
        ext = jnp.concatenate([x0.astype(x.dtype), x], axis=1)
    else:
        ext = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(ext[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :]


def rglru_forward(params, x, cfg: ArchConfig, h0=None, conv0=None,
                  return_state: bool = False):
    """x: (B,S,d) -> (B,S,d) [, (h_state, conv_state)]."""
    dtype = x.dtype
    y = jax.nn.gelu(x @ params["w_y"].astype(dtype), approximate=True)
    u = x @ params["w_x"].astype(dtype)
    uc = _causal_conv(u, params["conv_w"].astype(dtype),
                      params["conv_b"].astype(dtype), x0=conv0)

    a, bu = _gates(params, uc)                 # f32 (B,S,w)
    if h0 is not None:
        # fold the carried state into step 0: b0' = a0·h0 + b0
        bu = bu.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bu), axis=1)
    out = (h.astype(dtype) * y) @ params["w_out"].astype(dtype)
    if return_state:
        cw = cfg.rglru.conv_width
        S = x.shape[1]
        conv_state = u[:, -(cw - 1):, :] if S >= cw - 1 else \
            jnp.pad(u, ((0, 0), (cw - 1 - S, 0), (0, 0)))
        return out, (h[:, -1].astype(dtype), conv_state)
    return out


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    w = _lru_width(cfg)
    cw = cfg.rglru.conv_width
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, cw - 1, w), dtype),
    }


def rglru_decode(params, x, cache, cfg: ArchConfig):
    """One-token step. x: (B,1,d). Returns (out, new_cache)."""
    dtype = x.dtype
    xt = x[:, 0]
    y = jax.nn.gelu(xt @ params["w_y"].astype(dtype), approximate=True)
    u = xt @ params["w_x"].astype(dtype)

    window = jnp.concatenate([cache["conv"].astype(dtype), u[:, None]], 1)
    w = params["conv_w"].astype(dtype)
    uc = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(dtype)

    a, bu = _gates(params, uc)
    h = a * cache["h"].astype(jnp.float32) + bu
    out = ((h.astype(dtype) * y) @ params["w_out"].astype(dtype))[:, None]
    return out, {"h": h.astype(dtype), "conv": window[:, 1:]}
