"""Attention blocks: GQA/MQA, sliding-window, and MLA — prefill + decode.

The softmax attention core is a *chunked* (flash-style) pure-JAX
implementation: a ``lax.scan`` over query blocks keeps the live score tensor
at ``(B, H, q_chunk, Skv)`` so 32k-token prefill lowers without materializing
the full S×S score matrix. ``repro.kernels.flash_attention`` is the Pallas
TPU version of the same computation (same oracle).

Caches (DESIGN.md §6):
  * dense:  ``k``/``v`` ``(B, S_max, Hkv, dh)`` + per-request ``idx (B,)``;
            sharded batch→data, seq→model (context parallel on the TP axis).
  * swa:    ring buffer ``(B, window, Hkv, dh)`` + absolute-position array
            ``kpos (B, window)`` (−1 = empty); rope is applied at write time.
  * mla:    latent ``c_kv (B, S_max, kv_rank)`` + shared ``k_rope``; decode
            runs the *absorbed* form (attention in latent space).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init

NEG_INF = -2.3819763e38  # min bf16-representable-ish; safely below any score


class KVView(NamedTuple):
    """The narrow seam between the serving layer and the model: everything a
    mixed prefill+decode step needs to know about where its tokens live in
    the paged KV pools (``docs/serving.md``). A NamedTuple of arrays, so it
    is a jit-able pytree.

    ``block_tables[b, j]`` is the physical block holding request ``b``'s
    logical block ``j`` (padding rows/slots carry block 0 — their reads are
    masked by ``context_lens``). ``positions[b, s]`` is the absolute
    position of new token ``s`` of row ``b`` (−1 = padding: the token is
    neither written to the pool nor allowed to produce output).
    ``context_lens[b]`` counts the KV entries visible to row ``b`` AFTER
    this step's writes. ``last[b]`` indexes the row's last valid new token
    (0 for padding rows), where the step reads its logits."""

    block_tables: jnp.ndarray   # (B, MAX_BLOCKS) int32
    positions: jnp.ndarray      # (B, S_step) int32, −1 = padding
    context_lens: jnp.ndarray   # (B,) int32
    last: jnp.ndarray           # (B,) int32


def init_kv_pool(cfg: ArchConfig, num_blocks: int, block_size: int, dtype):
    """One layer's paged KV pool: ``num_blocks`` fixed-size blocks shared by
    every request (vs. the dense per-request ``(B, s_max)`` buffers)."""
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((num_blocks, block_size, Hkv, dh), dtype),
        "v": jnp.zeros((num_blocks, block_size, Hkv, dh), dtype),
    }


def paged_update(kp, vp, k_new, v_new, block_tables, positions):
    """Scatter this step's K/V into the pools through the block tables.

    kp/vp: (NB, BS, Hkv, dh); k_new/v_new: (B, S, Hkv, dh); positions:
    (B, S) absolute (−1 = padding → routed out of range and dropped).
    Distinct requests own distinct blocks and prefix-shared blocks are never
    written (reuse is capped below the first fed position), so scatter
    indices never collide."""
    NB, BS = kp.shape[0], kp.shape[1]
    pos = jnp.maximum(positions, 0)
    blk = jnp.take_along_axis(block_tables, pos // BS, axis=1)
    flat = jnp.where(positions >= 0, blk * BS + pos % BS, NB * BS)
    flat = flat.reshape(-1)
    tail = kp.shape[2:]
    kp = kp.reshape(NB * BS, *tail).at[flat].set(
        k_new.reshape(-1, *tail), mode="drop").reshape(NB, BS, *tail)
    vp = vp.reshape(NB * BS, *tail).at[flat].set(
        v_new.reshape(-1, *tail), mode="drop").reshape(NB, BS, *tail)
    return kp, vp


def paged_lookup(kp, vp, block_tables, context_lens):
    """Gather each row's KV context from the pools: returns
    (k, v, kv_positions) with k/v: (B, MAXB·BS, Hkv, dh) and kv_positions
    (B, MAXB·BS) absolute (−1 = beyond the row's context → masked with an
    exact-zero softmax weight, so ragged contexts stay bit-exact)."""
    B, MAXB = block_tables.shape
    NB, BS = kp.shape[0], kp.shape[1]
    k = kp[block_tables].reshape(B, MAXB * BS, *kp.shape[2:])
    v = vp[block_tables].reshape(B, MAXB * BS, *vp.shape[2:])
    base = jnp.arange(MAXB * BS)[None, :]
    kv_pos = jnp.where(base < context_lens[:, None], base, -1)
    return k, v, kv_pos


def attention_paged(params, x, pool, view: KVView, cfg: ArchConfig, *,
                    window: int = 0):
    """One mixed prefill/decode step against a paged pool: project the new
    tokens, write them through the block tables, attend over each row's
    gathered context. x: (B, S_step, d). Returns (out, new_pool)."""
    B, S, _ = x.shape
    dtype = x.dtype
    pos = jnp.maximum(view.positions, 0)
    q, k, v = _project_qkv(params, x, cfg, pos, dtype)
    kp, vp = paged_update(pool["k"], pool["v"], k, v, view.block_tables,
                          view.positions)
    kk, vv, kv_pos = paged_lookup(kp, vp, view.block_tables,
                                  view.context_lens)
    o = attention_core(q, kk, vv, q_positions=view.positions,
                       kv_positions=kv_pos, causal=True, window=window)
    out = o.reshape(B, S, -1) @ params["wo"].astype(dtype)
    return out, {"k": kp, "v": vp}


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention core
# ---------------------------------------------------------------------------


def _pick_chunk(s: int, target: int = 256) -> int:
    c = min(s, target)
    while s % c:
        c //= 2
    return max(c, 1)


def attention_core(
    q: jnp.ndarray,           # (B, Sq, H, dh)
    k: jnp.ndarray,           # (B, Skv, Hkv, dh)
    v: jnp.ndarray,           # (B, Skv, Hkv, dv)
    *,
    q_positions: jnp.ndarray,   # (B, Sq) absolute positions
    kv_positions: jnp.ndarray,  # (B, Skv) absolute positions (−1 = masked)
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,   # prefix-LM: bidirectional attention inside prefix
    scale: Optional[float] = None,
    q_chunk: int = 256,
) -> jnp.ndarray:
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, dv = v.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    qg = q.reshape(B, Sq, Hkv, G, dh)

    def block(q_blk, qpos_blk):
        # q_blk: (B, qc, Hkv, G, dh); scores (B, Hkv, G, qc, Skv) in f32
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        valid = (kv_positions >= 0)[:, None, None, None, :]
        if causal:
            rel = (kv_positions[:, None, :] <= qpos_blk[:, :, None])
            if prefix_len:
                both = ((kv_positions[:, None, :] < prefix_len)
                        & (qpos_blk[:, :, None] < prefix_len))
                rel = rel | both
            valid = valid & rel[:, None, None, :, :]
            if window:
                near = (kv_positions[:, None, :]
                        > qpos_blk[:, :, None] - window)
                valid = valid & near[:, None, None, :, :]
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o.reshape(q_blk.shape[0], q_blk.shape[1], H, dv)

    qc = _pick_chunk(Sq, q_chunk)
    if qc == Sq:
        return block(qg, q_positions)

    n = Sq // qc
    qg_s = qg.reshape(B, n, qc, Hkv, G, dh).transpose(1, 0, 2, 3, 4, 5)
    qpos_s = q_positions.reshape(B, n, qc).transpose(1, 0, 2)

    def body(_, xs):
        qb, pb = xs
        return None, block(qb, pb)

    _, outs = jax.lax.scan(body, None, (qg_s, qpos_s))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, dv)


# ---------------------------------------------------------------------------
# Standard GQA / MQA / SWA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype=jnp.float32):
    d, H, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, Hkv * dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, Hkv * dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * dh, d), in_axis_size=H * dh, dtype=dtype),
    }


def _project_qkv(params, x, cfg: ArchConfig, positions, dtype):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"].astype(dtype)).reshape(B, S, H, dh)
    k = (x @ params["wk"].astype(dtype)).reshape(B, S, Hkv, dh)
    v = (x @ params["wv"].astype(dtype)).reshape(B, S, Hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_forward(params, x, cfg: ArchConfig, *, window: int = 0,
                      prefix_len: int = 0,
                      positions: Optional[jnp.ndarray] = None):
    """Training / prefill forward (no cache returned)."""
    B, S, _ = x.shape
    dtype = x.dtype
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, x, cfg, positions, dtype)
    o = attention_core(q, k, v, q_positions=positions, kv_positions=positions,
                       causal=True, window=window, prefix_len=prefix_len)
    return o.reshape(B, S, -1) @ params["wo"].astype(dtype)


# ----- caches ---------------------------------------------------------------


def init_dense_cache(cfg: ArchConfig, batch: int, s_max: int, dtype):
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s_max, Hkv, dh), dtype),
        "v": jnp.zeros((batch, s_max, Hkv, dh), dtype),
    }


def init_swa_cache(cfg: ArchConfig, batch: int, window: int, dtype):
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, window, Hkv, dh), dtype),
        "v": jnp.zeros((batch, window, Hkv, dh), dtype),
        "kpos": jnp.full((batch, window), -1, jnp.int32),
    }


def _write_at(buf, new, idx):
    """Per-request dynamic update: buf (B, S, ...), new (B, 1, ...), idx (B,)."""
    def one(b, n, i):
        return jax.lax.dynamic_update_slice_in_dim(b, n, i, axis=0)
    return jax.vmap(one)(buf, new, idx)


def attention_prefill(params, x, cfg: ArchConfig, *, window: int = 0,
                      s_max: Optional[int] = None):
    """Forward + build the decode cache. Returns (out, cache)."""
    B, S, _ = x.shape
    dtype = x.dtype
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, x, cfg, positions, dtype)
    o = attention_core(q, k, v, q_positions=positions, kv_positions=positions,
                       causal=True, window=window)
    out = o.reshape(B, S, -1) @ params["wo"].astype(dtype)

    if window:
        W = window
        cache = init_swa_cache(cfg, B, W, dtype)
        take = min(S, W)
        pos = jnp.arange(S - take, S)
        slots = pos % W
        cache = {
            "k": cache["k"].at[:, slots].set(k[:, S - take:]),
            "v": cache["v"].at[:, slots].set(v[:, S - take:]),
            "kpos": cache["kpos"].at[:, slots].set(
                jnp.broadcast_to(pos, (B, take))),
        }
    else:
        s_max = s_max or S
        cache = init_dense_cache(cfg, B, s_max, dtype)
        cache = {
            "k": cache["k"].at[:, :S].set(k),
            "v": cache["v"].at[:, :S].set(v),
        }
    return out, cache


def attention_decode(params, x, cache, idx, cfg: ArchConfig, *,
                     window: int = 0):
    """One decode step. x: (B, 1, d); idx: (B,) position of the new token.
    Returns (out, new_cache)."""
    B, _, _ = x.shape
    dtype = x.dtype
    positions = idx[:, None]
    q, k, v = _project_qkv(params, x, cfg, positions, dtype)

    if window:
        W = cache["k"].shape[1]
        slot = (idx % W)[:, None]
        new_cache = {
            "k": _write_at(cache["k"], k, slot[:, 0]),
            "v": _write_at(cache["v"], v, slot[:, 0]),
            "kpos": jax.vmap(
                lambda kp, s, i: kp.at[s].set(i))(cache["kpos"], slot[:, 0], idx),
        }
        kv_pos = new_cache["kpos"]
    else:
        new_cache = {
            "k": _write_at(cache["k"], k, idx),
            "v": _write_at(cache["v"], v, idx),
        }
        S_max = cache["k"].shape[1]
        base = jnp.arange(S_max)[None, :]
        kv_pos = jnp.where(base <= idx[:, None], base, -1)

    o = attention_core(q, new_cache["k"], new_cache["v"],
                       q_positions=positions, kv_positions=kv_pos,
                       causal=True, window=window)
    out = o.reshape(B, 1, -1) @ params["wo"].astype(dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk),
                           in_axis_size=m.q_lora_rank, dtype=dtype),
        # d -> kv latent + shared rope key
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype=dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        # latent -> per-head nope-key and value
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim),
                           in_axis_size=m.kv_lora_rank, dtype=dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim),
                           in_axis_size=m.kv_lora_rank, dtype=dtype),
        "wo": dense_init(ks[5], (H * m.v_head_dim, d),
                         in_axis_size=H * m.v_head_dim, dtype=dtype),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _mla_q(params, x, cfg, positions, dtype):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cq = _rms(x @ params["wq_a"].astype(dtype), params["q_norm"])
    q = (cq @ params["wq_b"].astype(dtype)).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, x, cfg, positions, dtype):
    m = cfg.mla
    ckv_full = x @ params["wkv_a"].astype(dtype)
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = _rms(c_kv, params["kv_norm"])
    # shared (per-token, head-broadcast) rope key
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(params, x, cfg: ArchConfig,
                positions: Optional[jnp.ndarray] = None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dtype = x.dtype
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(params, x, cfg, positions, dtype)
    c_kv, k_rope = _mla_latents(params, x, cfg, positions, dtype)
    k_nope = (c_kv @ params["wk_b"].astype(dtype)).reshape(
        B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["wv_b"].astype(dtype)).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], -1)
    o = attention_core(
        q, k, v, q_positions=positions, kv_positions=positions, causal=True,
        scale=1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim))
    return o.reshape(B, S, -1) @ params["wo"].astype(dtype)


def init_mla_cache(cfg: ArchConfig, batch: int, s_max: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, s_max, m.qk_rope_head_dim), dtype),
    }


def mla_prefill(params, x, cfg: ArchConfig, *, s_max: Optional[int] = None):
    B, S, _ = x.shape
    dtype = x.dtype
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = mla_forward(params, x, cfg, positions)
    c_kv, k_rope = _mla_latents(params, x, cfg, positions, dtype)
    s_max = s_max or S
    cache = init_mla_cache(cfg, B, s_max, dtype)
    cache = {
        "c_kv": cache["c_kv"].at[:, :S].set(c_kv),
        "k_rope": cache["k_rope"].at[:, :S].set(k_rope),
    }
    return out, cache


def mla_decode(params, x, cache, idx, cfg: ArchConfig):
    """Absorbed-form decode: attention runs in the kv_rank latent space, so
    per-step compute is O(S·kv_rank) instead of O(S·H·dh) — the production
    MLA path. Returns (out, new_cache)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    dtype = x.dtype
    positions = idx[:, None]

    q_nope, q_rope = _mla_q(params, x, cfg, positions, dtype)  # (B,1,H,·)
    c_new, kr_new = _mla_latents(params, x, cfg, positions, dtype)
    cache = {
        "c_kv": _write_at(cache["c_kv"], c_new, idx),
        "k_rope": _write_at(cache["k_rope"], kr_new, idx),
    }
    c_kv, k_rope = cache["c_kv"], cache["k_rope"]  # (B,S,r), (B,S,rr)
    S_max = c_kv.shape[1]

    # absorb W_k_b into the query: q_lat (B,1,H,r)
    wk_b = params["wk_b"].astype(dtype).reshape(m.kv_lora_rank, H,
                                                m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    base = jnp.arange(S_max)[None, :]
    valid = (base <= idx[:, None])[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", p.astype(dtype), c_kv)
    wv_b = params["wv_b"].astype(dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b)
    out = o.reshape(B, 1, -1) @ params["wo"].astype(dtype)
    return out, cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: ArchConfig, dtype=jnp.float32):
    return init_attention(key, cfg, dtype)


def cross_attention_kv(params, enc_out, cfg: ArchConfig):
    """Precompute encoder K/V once per request (prefill of the cross cache)."""
    B, T, _ = enc_out.shape
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    dtype = enc_out.dtype
    k = (enc_out @ params["wk"].astype(dtype)).reshape(B, T, Hkv, dh)
    v = (enc_out @ params["wv"].astype(dtype)).reshape(B, T, Hkv, dh)
    return {"k": k, "v": v}


def cross_attention(params, x, cross_kv, cfg: ArchConfig):
    B, S, _ = x.shape
    H, dh = cfg.num_heads, cfg.resolved_head_dim
    dtype = x.dtype
    q = (x @ params["wq"].astype(dtype)).reshape(B, S, H, dh)
    T = cross_kv["k"].shape[1]
    qpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    kpos = jnp.broadcast_to(jnp.arange(T), (B, T))
    o = attention_core(q, cross_kv["k"], cross_kv["v"], q_positions=qpos,
                       kv_positions=kpos, causal=False)
    return o.reshape(B, S, -1) @ params["wo"].astype(dtype)
