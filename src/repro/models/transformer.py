"""Decoder-only LM assembled from an ArchConfig.

The layer stack runs as a ``lax.scan`` over *pattern periods* (DESIGN.md §3):
params for each entry of ``cfg.layer_pattern`` are stacked over the number of
full periods, so HLO size (and compile time) is O(period), not O(num_layers).
Remainder layers (num_layers % period) are unrolled. KV/state caches follow
the same layout and are scanned alongside params during prefill/decode.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, embed_init, init_norm, softcap
from repro.runtime import Runtime

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _has_ffn(cfg: ArchConfig) -> bool:
    return cfg.d_ff > 0 or cfg.moe is not None


def init_block(key, kind: str, cfg: ArchConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind in ("attn", "swa"):
        p["mixer"] = attn.init_attention(k1, cfg, dtype)
    elif kind == "mla":
        p["mixer"] = attn.init_mla(k1, cfg, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm_mod.init_ssm(k1, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg):
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["ffn"] = ffn_mod.init_ffn(k2, cfg, dtype)
    return p


def _mixer_forward(kind, params, x, cfg, prefix_len=0):
    if kind == "attn":
        return attn.attention_forward(params, x, cfg, window=0,
                                      prefix_len=prefix_len)
    if kind == "swa":
        return attn.attention_forward(params, x, cfg, window=cfg.window,
                                      prefix_len=prefix_len)
    if kind == "mla":
        return attn.mla_forward(params, x, cfg)
    if kind == "ssm":
        return ssm_mod.ssm_forward(params, x, cfg)
    if kind == "rglru":
        return rglru_mod.rglru_forward(params, x, cfg)
    raise ValueError(kind)


def _tp_context(rt: Runtime):
    """Build a TPContext (via the one ``TPConfig → TPContext.from_config``
    path) when an explicit collective backend is active (backends with
    ``explicit = False`` — e.g. ``auto`` — leave scheduling to XLA and run
    without shard_map)."""
    from repro.core.backends import get_backend
    from repro.core.tp import TPContext

    backend = get_backend(rt.tp.mode)
    mesh = sharding.current_mesh()
    if (not backend.explicit or mesh is None
            or sharding.tp_size(mesh) <= 1):
        return None
    return TPContext.from_config(rt.tp, mesh)


def _sp_axis(rt: Runtime, x):
    """Sequence-parallel shard axis for a (B, S, d) activation — only when
    the sequence actually divides over the model axis. Ragged/decode
    sequences (S % axis != 0, incl. S=1) stay replicated instead of hitting
    an unsatisfiable sharding constraint."""
    if not rt.tp.sequence_parallel or x.shape[1] <= 1:
        return None
    mesh = sharding.current_mesh()
    n = sharding.tp_size(mesh)
    return sharding.tp_axes(mesh) if n > 1 and x.shape[1] % n == 0 else None


def _whole_block_applicable(cfg: ArchConfig, kind: str, tp: int,
                            route_ring: Optional[int] = None) -> bool:
    """Can this block run as ONE dataflow graph (attention AND FFN/MoE side
    both explicit-TP-applicable)? Shared by the per-block and period paths
    so their gating cannot drift apart. ``route_ring`` is the MoE routing
    ring (== tp on a flat mesh; the ``tp_out`` size on a 2D mesh, where
    experts shard only over the slow axis — grouped EP)."""
    from repro.core import tp as tp_mod

    return (kind in ("attn", "swa") and tp_mod.tp_applicable(cfg, kind, tp)
            and _has_ffn(cfg)
            and (tp_mod.tp_applicable(cfg, "moe", tp, route_ring)
                 or tp_mod.tp_applicable(cfg, "ffn", tp)))


def block_forward(kind, params, x, cfg: ArchConfig, rt: Runtime,
                  prefix_len: int = 0):
    """Pre-norm residual block. Returns (x, aux_loss).

    When the whole block is TP-applicable (attention AND dense-FFN/MoE), it
    runs as ONE dataflow graph in one ``shard_map`` (``tp_mod.sp_block``):
    the graph spans the attention-out → FFN-in seam, so the optimizer's
    pass 2 fuses RS → residual → LN → AG across the sub-layer boundary and
    MoE routing goes through the IR. When the sequence can't be sharded
    over the ring (decode S=1, ragged S % tp != 0) dense blocks fall back
    *per-collective*, not per-block: the same graph without the sequence
    sharding — column/row-sharded GEMMs with one backend-dispatched
    allreduce (``gemm_ar``) per sub-layer. Blocks where only one side is
    applicable fall back to the per-sub-layer graphs below."""
    from repro.core import tp as tp_mod

    tpc = _tp_context(rt)
    dtype = x.dtype

    # ----- whole block as one dataflow graph -----
    whole = tpc is not None and _whole_block_applicable(cfg, kind, tpc.tp,
                                                        tpc.route_ring)
    if whole and x.shape[1] % tpc.tp == 0:
        x, aux = tp_mod.sp_block(tpc, x, params, cfg, kind,
                                 prefix_len=prefix_len, norm_kind=cfg.norm)
        x = sharding.shard(x, sharding.BATCH_AXES, _sp_axis(rt, x), None)
        return x, aux
    if whole and x.shape[1] % tpc.tp != 0 and cfg.moe is None:
        x, aux = tp_mod.sp_block(tpc, x, params, cfg, kind,
                                 prefix_len=prefix_len, norm_kind=cfg.norm,
                                 seq_sharded=False)
        x = sharding.shard(x, sharding.BATCH_AXES, None, None)
        return x, aux

    # ----- mixer -----
    if tpc is not None and tp_mod.tp_applicable(cfg, kind, tpc.tp) \
            and x.shape[1] % tpc.tp == 0:
        m = params["mixer"]
        x = x + tp_mod.sp_attention(
            tpc, x, params["norm1"]["scale"].astype(dtype),
            m["wq"].astype(dtype), m["wk"].astype(dtype),
            m["wv"].astype(dtype), m["wo"].astype(dtype), cfg,
            window=cfg.window if kind == "swa" else 0, prefix_len=prefix_len,
            norm_kind=cfg.norm)
    else:
        h = apply_norm(cfg.norm, params["norm1"], x)
        x = x + _mixer_forward(kind, params["mixer"], h, cfg, prefix_len)

    # ----- ffn -----
    aux = jnp.float32(0.0)
    if _has_ffn(cfg):
        if tpc is not None \
                and tp_mod.tp_applicable(cfg, "moe", tpc.tp, tpc.route_ring) \
                and x.shape[1] % tpc.tp == 0:
            out, aux = tp_mod.sp_moe_ffn(
                tpc, x, params["norm2"]["scale"].astype(dtype),
                params["ffn"], cfg, norm_kind=cfg.norm)
            x = x + out
        elif tpc is not None and tp_mod.tp_applicable(cfg, "ffn", tpc.tp) \
                and x.shape[1] % tpc.tp == 0:
            f = params["ffn"]
            x = x + tp_mod.sp_ffn(
                tpc, x, params["norm2"]["scale"].astype(dtype),
                f["w_up"].astype(dtype),
                f["w_gate"].astype(dtype) if "w_gate" in f else None,
                f["w_down"].astype(dtype), cfg.act, norm_kind=cfg.norm)
        else:
            h = apply_norm(cfg.norm, params["norm2"], x)
            out, aux = ffn_mod.ffn_forward(params["ffn"], h, cfg)
            x = x + out
    x = sharding.shard(x, sharding.BATCH_AXES, _sp_axis(rt, x), None)
    return x, aux


def _mixer_prefill(kind, params, x, cfg, s_max):
    if kind == "attn":
        return attn.attention_prefill(params, x, cfg, window=0, s_max=s_max)
    if kind == "swa":
        return attn.attention_prefill(params, x, cfg, window=cfg.window)
    if kind == "mla":
        return attn.mla_prefill(params, x, cfg, s_max=s_max)
    if kind == "ssm":
        out, (h, conv) = ssm_mod.ssm_forward(params, x, cfg, return_state=True)
        return out, {"h": h, "conv": conv}
    if kind == "rglru":
        out, (h, conv) = rglru_mod.rglru_forward(params, x, cfg,
                                                 return_state=True)
        return out, {"h": h, "conv": conv}
    raise ValueError(kind)


def block_prefill(kind, params, x, cfg, rt: Runtime, s_max):
    h = apply_norm(cfg.norm, params["norm1"], x)
    mixed, cache = _mixer_prefill(kind, params["mixer"], h, cfg, s_max)
    x = x + mixed
    if _has_ffn(cfg):
        h = apply_norm(cfg.norm, params["norm2"], x)
        out, _ = ffn_mod.ffn_forward(params["ffn"], h, cfg)
        x = x + out
    x = sharding.shard(x, sharding.BATCH_AXES, _sp_axis(rt, x), None)
    return x, cache


def _mixer_decode(kind, params, x, cache, idx, cfg):
    if kind == "attn":
        return attn.attention_decode(params, x, cache, idx, cfg, window=0)
    if kind == "swa":
        return attn.attention_decode(params, x, cache, idx, cfg,
                                     window=cfg.window)
    if kind == "mla":
        return attn.mla_decode(params, x, cache, idx, cfg)
    if kind == "ssm":
        return ssm_mod.ssm_decode(params, x, cache, cfg)
    if kind == "rglru":
        return rglru_mod.rglru_decode(params, x, cache, cfg)
    raise ValueError(kind)


def block_decode(kind, params, x, cache, idx, cfg, rt: Runtime):
    h = apply_norm(cfg.norm, params["norm1"], x)
    mixed, cache = _mixer_decode(kind, params["mixer"], h, cache, idx, cfg)
    x = x + mixed
    if _has_ffn(cfg):
        h = apply_norm(cfg.norm, params["norm2"], x)
        out, _ = ffn_mod.ffn_forward(params["ffn"], h, cfg)
        x = x + out
    x = sharding.shard(x, sharding.BATCH_AXES, None, None)
    return x, cache


def block_step(kind, params, x, pool, view, cfg, rt: Runtime):
    """Pre-norm residual block for one mixed prefill/decode serving step
    against a paged KV pool (:class:`repro.models.attention.KVView` is the
    seam). Plain-math fallback of the period-level graph path in
    :func:`_blocks_step`. Returns (x, new_pool)."""
    window = cfg.window if kind == "swa" else 0
    h = apply_norm(cfg.norm, params["norm1"], x)
    mixed, pool = attn.attention_paged(params["mixer"], h, pool, view, cfg,
                                       window=window)
    x = x + mixed
    if _has_ffn(cfg):
        h = apply_norm(cfg.norm, params["norm2"], x)
        out, _ = ffn_mod.ffn_forward(params["ffn"], h, cfg)
        x = x + out
    x = sharding.shard(x, sharding.BATCH_AXES, None, None)
    return x, pool


def _blocks_step(kinds, params_seq, x, pools_seq, view, cfg: ArchConfig,
                 rt: Runtime):
    """Run consecutive blocks of a serving step. When every block is
    whole-block TP-applicable the period executes as ONE dataflow graph in
    one ``shard_map`` (:func:`repro.core.tp.sp_serve_period`): the KV pools
    and block tables ride through the graph as extra inputs/outputs of the
    attention ``custom`` node, the out-projection/FFN reductions fuse to
    backend-dispatched ``gemm_ar`` (pass 1 — the decode/ragged TP schedule,
    S=1 and S % tp ≠ 0 alike), and ``TPConfig(planner="perfsim")`` plans the
    mixed-batch period graph. Pools are unbatched shared state, so the graph
    path additionally requires dp == 1 (no data axis to diverge replicas
    over); otherwise falls back per block."""
    from repro.core import tp as tp_mod

    tpc = _tp_context(rt)
    if (tpc is not None and len(params_seq) > 0 and cfg.moe is None
            and all(k in ("attn", "swa") for k in kinds)
            and all(_whole_block_applicable(cfg, k, tpc.tp, tpc.route_ring)
                    for k in kinds)
            and sharding.dp_size(tpc.mesh) <= 1):
        x, pools = tp_mod.sp_serve_period(tpc, x, params_seq, cfg, kinds,
                                          pools_seq, view,
                                          norm_kind=cfg.norm)
        x = sharding.shard(x, sharding.BATCH_AXES, None, None)
        return x, pools
    new_pools = []
    for kind, p, pl in zip(kinds, params_seq, pools_seq):
        x, pl = block_step(kind, p, x, pl, view, cfg, rt)
        new_pools.append(pl)
    return x, new_pools


def init_block_cache(kind, cfg: ArchConfig, batch: int, s_max: int, dtype):
    if kind == "attn":
        return attn.init_dense_cache(cfg, batch, s_max, dtype)
    if kind == "swa":
        return attn.init_swa_cache(cfg, batch, cfg.window, dtype)
    if kind == "mla":
        return attn.init_mla_cache(cfg, batch, s_max, dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def cache_pspec(kind: str, cfg: ArchConfig):
    """PartitionSpec entries per cache leaf: batch→data axes; the long axis
    (cache sequence / state width / heads) → the TP axes (context
    parallelism; the composite ``(tp_in, tp_out)`` tuple on 2D meshes)."""
    B = sharding.BATCH_AXES
    M = sharding.tp_axes(sharding.current_mesh())
    if kind in ("attn", "swa"):
        spec = {"k": (B, M, None, None), "v": (B, M, None, None)}
        if kind == "swa":
            spec["kpos"] = (B, M)
        return spec
    if kind == "mla":
        return {"c_kv": (B, M, None), "k_rope": (B, M, None)}
    if kind == "ssm":
        return {"h": (B, M, None, None), "conv": (B, None, M)}
    if kind == "rglru":
        return {"h": (B, M), "conv": (B, None, M)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack (scan over pattern periods)
# ---------------------------------------------------------------------------


def _pattern_split(cfg: ArchConfig):
    pattern = cfg.layer_pattern
    P = len(pattern)
    n_full = cfg.num_layers // P
    rem = cfg.layer_kinds()[n_full * P:]
    return pattern, P, n_full, rem


def init_stack(key, cfg: ArchConfig, dtype):
    pattern, P, n_full, rem = _pattern_split(cfg)
    keys = jax.random.split(key, len(pattern) + len(rem))
    params: Params = {"periods": {}, "rem": []}
    for i, kind in enumerate(pattern):
        if n_full:
            params["periods"][f"b{i}"] = jax.vmap(
                lambda k, kind=kind: init_block(k, kind, cfg, dtype)
            )(jax.random.split(keys[i], n_full))
    for j, kind in enumerate(rem):
        params["rem"].append(init_block(keys[len(pattern) + j], kind, cfg, dtype))
    return params


def _blocks_forward(kinds, params_seq, x, cfg: ArchConfig, rt: Runtime,
                    prefix_len: int = 0):
    """Run consecutive blocks. When EVERY block is whole-block TP-applicable
    the run executes as ONE period-level dataflow graph in one ``shard_map``
    (``tp_mod.sp_period``) — the optimizer sees the block→block seams, so
    pass 2's cross-block RS→residual→LN→AG fusion and pass 3's asymmetric
    pairing fire inside the model path. ``rt.tp.microbatches`` (via
    ``TPContext``) additionally splits the period into independent
    microbatch chains inside that one graph, the structure pass 3 needs to
    emit ``overlap_asym`` at all on a straight-line period. Otherwise falls
    back per block."""
    from repro.core import tp as tp_mod

    tpc = _tp_context(rt)
    if (tpc is not None and len(params_seq) > 0
            and x.shape[1] % tpc.tp == 0
            and all(_whole_block_applicable(cfg, k, tpc.tp, tpc.route_ring)
                    for k in kinds)):
        x, aux = tp_mod.sp_period(tpc, x, params_seq, cfg, kinds,
                                  prefix_len=prefix_len, norm_kind=cfg.norm)
        x = sharding.shard(x, sharding.BATCH_AXES, _sp_axis(rt, x), None)
        return x, aux
    aux = jnp.float32(0.0)
    for kind, p in zip(kinds, params_seq):
        x, a = block_forward(kind, p, x, cfg, rt, prefix_len)
        aux = aux + a
    return x, aux


def stack_forward(params, x, cfg: ArchConfig, rt: Runtime,
                  prefix_len: int = 0):
    pattern, P, n_full, rem = _pattern_split(cfg)

    def period_fwd(carry, pslice):
        x, aux = carry
        x, a = _blocks_forward(pattern, [pslice[f"b{i}"] for i in range(P)],
                               x, cfg, rt, prefix_len)
        return (x, aux + a), None

    def tail_fwd(x, ps):
        return _blocks_forward(rem, ps, x, cfg, rt, prefix_len)

    # remat covers the scanned periods AND the remainder tail — a stack with
    # num_layers % len(pattern) != 0 must not silently keep tail activations
    body = jax.checkpoint(period_fwd) if rt.remat else period_fwd
    tail = jax.checkpoint(tail_fwd) if rt.remat else tail_fwd
    aux = jnp.float32(0.0)
    if n_full:
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["periods"])
    if rem:
        x, a = tail(x, params["rem"])
        aux = aux + a
    return x, aux


def stack_prefill(params, x, cfg: ArchConfig, rt: Runtime, s_max: int):
    pattern, P, n_full, rem = _pattern_split(cfg)

    def period_pf(x, pslice):
        caches = {}
        for i, kind in enumerate(pattern):
            x, caches[f"b{i}"] = block_prefill(kind, pslice[f"b{i}"], x, cfg,
                                               rt, s_max)
        return x, caches

    caches: Params = {"periods": {}, "rem": []}
    if n_full:
        x, caches["periods"] = jax.lax.scan(period_pf, x, params["periods"])
    for p, kind in zip(params["rem"], rem):
        x, c = block_prefill(kind, p, x, cfg, rt, s_max)
        caches["rem"].append(c)
    return x, caches


def stack_decode(params, x, caches, idx, cfg: ArchConfig, rt: Runtime):
    pattern, P, n_full, rem = _pattern_split(cfg)

    def period_dec(x, slices):
        pslice, cslice = slices
        new_c = {}
        for i, kind in enumerate(pattern):
            x, new_c[f"b{i}"] = block_decode(kind, pslice[f"b{i}"], x,
                                             cslice[f"b{i}"], idx, cfg, rt)
        return x, new_c

    new_caches: Params = {"periods": {}, "rem": []}
    if n_full:
        x, new_caches["periods"] = jax.lax.scan(
            period_dec, x, (params["periods"], caches["periods"]))
    for p, c, kind in zip(params["rem"], caches["rem"], rem):
        x, nc = block_decode(kind, p, x, c, idx, cfg, rt)
        new_caches["rem"].append(nc)
    return x, new_caches


def stack_step(params, x, pools, view, cfg: ArchConfig, rt: Runtime):
    """One mixed prefill/decode serving step through the whole stack: the
    paged analogue of :func:`stack_decode`, scanning period pools alongside
    period params. Supported mixers: attn/swa (gated by the engine)."""
    pattern, P, n_full, rem = _pattern_split(cfg)

    def period_step(x, slices):
        pslice, plslice = slices
        x, outs = _blocks_step(pattern, [pslice[f"b{i}"] for i in range(P)],
                               x, [plslice[f"b{i}"] for i in range(P)],
                               view, cfg, rt)
        return x, {f"b{i}": outs[i] for i in range(P)}

    new_pools: Params = {"periods": {}, "rem": []}
    if n_full:
        x, new_pools["periods"] = jax.lax.scan(
            period_step, x, (params["periods"], pools["periods"]))
    if rem:
        x, outs = _blocks_step(rem, params["rem"], x, pools["rem"], view,
                               cfg, rt)
        new_pools["rem"] = list(outs)
    return x, new_pools


def init_stack_pools(cfg: ArchConfig, num_blocks: int, block_size: int,
                     dtype):
    """Paged KV pools for the whole stack, laid out like the stack cache
    (stacked over full periods + an unrolled remainder) so the serving scan
    carries them alongside params."""
    pattern, P, n_full, rem = _pattern_split(cfg)
    pools: Params = {"periods": {}, "rem": []}
    for i, kind in enumerate(pattern):
        if n_full:
            one = attn.init_kv_pool(cfg, num_blocks, block_size, dtype)
            pools["periods"][f"b{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_full,) + a.shape), one)
    for kind in rem:
        pools["rem"].append(attn.init_kv_pool(cfg, num_blocks, block_size,
                                              dtype))
    return pools


def pool_pspec(cfg: ArchConfig):
    """PartitionSpec entries for one (num_blocks, block_size, Hkv, dh) pool:
    KV heads shard over the model axis when divisible, else replicate (the
    GQA replicated-KV layout — every device computes the full K/V
    deterministically, so replicas stay consistent)."""
    mesh = sharding.current_mesh()
    tp = sharding.tp_size(mesh)
    head = sharding.tp_axes(mesh) if tp > 1 and cfg.num_kv_heads % tp == 0 \
        else None
    return (None, None, head, None)


def shard_stack_pools(pools, cfg: ArchConfig):
    """Apply sharding constraints to a stack-pools pytree."""
    spec = pool_pspec(cfg)

    def do(tree, stacked):
        return {name: sharding.shard(leaf, *((None,) if stacked else ())
                                     + spec)
                for name, leaf in tree.items()}

    out: Params = {"periods": {}, "rem": []}
    for name, tree in pools["periods"].items():
        out["periods"][name] = do(tree, True)
    for tree in pools["rem"]:
        out["rem"].append(do(tree, False))
    return out


def init_stack_cache(cfg: ArchConfig, batch: int, s_max: int, dtype):
    pattern, P, n_full, rem = _pattern_split(cfg)
    caches: Params = {"periods": {}, "rem": []}
    for i, kind in enumerate(pattern):
        if n_full:
            one = init_block_cache(kind, cfg, batch, s_max, dtype)
            caches["periods"][f"b{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_full,) + a.shape), one)
    for kind in rem:
        caches["rem"].append(init_block_cache(kind, cfg, batch, s_max, dtype))
    return caches


def shard_stack_cache(caches, cfg: ArchConfig):
    """Apply sharding constraints to a stack cache pytree."""
    pattern, P, n_full, rem = _pattern_split(cfg)

    def do(tree, kind, stacked):
        spec = cache_pspec(kind, cfg)
        return {
            name: sharding.shard(leaf, *((None,) if stacked else ())
                                 + tuple(spec[name]))
            for name, leaf in tree.items()
        }

    out: Params = {"periods": {}, "rem": []}
    for i, kind in enumerate(pattern):
        if n_full:
            out["periods"][f"b{i}"] = do(caches["periods"][f"b{i}"], kind, True)
    for c, kind in zip(caches["rem"], rem):
        out["rem"].append(do(c, kind, False))
    return out


# ---------------------------------------------------------------------------
# LM head / loss
# ---------------------------------------------------------------------------


def chunked_ce_loss(x, embed_or_head, labels, mask, cfg: ArchConfig,
                    rt: Runtime, tied: bool):
    """Cross-entropy with logits computed per sequence chunk (bounds the
    (B, Sc, V) tensor for 256k-vocab archs). x: (B,S,d)."""
    B, S, d = x.shape
    chunk = min(rt.loss_chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk

    w = embed_or_head  # (V, d) if tied else (d, V)

    def chunk_loss(xc, yc, mc):
        dtype = xc.dtype
        logits = xc @ (w.T if tied else w).astype(dtype)
        logits = softcap(logits.astype(jnp.float32), cfg.logits_softcap)
        logits = sharding.shard(logits, sharding.BATCH_AXES, None,
                                sharding.tp_axes(sharding.current_mesh()))
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, yc[..., None], -1)[..., 0]
        nll = (lse - gold) * mc
        return jnp.sum(nll), jnp.sum(mc)

    if n == 1:
        tot, cnt = chunk_loss(x, labels, mask.astype(jnp.float32))
    else:
        xs = (x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3),
              labels.reshape(B, n, chunk).transpose(1, 0, 2),
              mask.astype(jnp.float32).reshape(B, n, chunk).transpose(1, 0, 2))

        def body(carry, inp):
            tot, cnt = carry
            t, c = chunk_loss(*inp)
            return (tot + t, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# LM — the top-level decoder-only model
# ---------------------------------------------------------------------------

AUX_LOSS_WEIGHT = 0.01


class LM:
    """Decoder-only language model (all non-enc-dec archs)."""

    def __init__(self, cfg: ArchConfig, rt: Runtime = Runtime()):
        self.cfg = cfg
        self.rt = rt

    # ----- params -----
    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.rt.pdtype
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p: Params = {
            "embed": embed_init(k1, (cfg.vocab_size, cfg.d_model), dtype),
            "stack": init_stack(k2, cfg, dtype),
            "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(k3, (cfg.d_model, cfg.vocab_size), dtype)
        return p

    def _head(self, params):
        tied = self.cfg.tie_embeddings
        return (params["embed"] if tied else params["lm_head"]), tied

    def _embed(self, params, tokens, dtype):
        e = params["embed"].astype(dtype)[tokens]
        return sharding.shard(e, sharding.BATCH_AXES, None, None)

    # ----- training -----
    def forward(self, params, tokens):
        """Full hidden states (B,S,d) — logits computed by the loss/head."""
        dtype = self.rt.dtype
        x = self._embed(params, tokens, dtype)
        x, aux = stack_forward(params["stack"], x, self.cfg, self.rt)
        x = apply_norm(self.cfg.norm, params["final_norm"], x)
        return x, aux

    def loss(self, params, batch) -> jnp.ndarray:
        tokens, labels = batch["tokens"], batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        x, aux = self.forward(params, tokens)
        head, tied = self._head(params)
        ce = chunked_ce_loss(x, head, labels, mask, self.cfg, self.rt, tied)
        return ce + AUX_LOSS_WEIGHT * aux

    # ----- serving -----
    def logits(self, params, x):
        head, tied = self._head(params)
        logits = x @ (head.T if tied else head).astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32), self.cfg.logits_softcap)
        return sharding.shard(logits, sharding.BATCH_AXES, None,
                              sharding.tp_axes(sharding.current_mesh()))

    def prefill(self, params, tokens, s_max: Optional[int] = None):
        """Returns (last-position logits, caches). ``tokens`` may be the raw
        (B,S) array or a batch dict with a "tokens" entry (uniform API)."""
        if isinstance(tokens, dict):
            tokens = tokens["tokens"]
        dtype = self.rt.dtype
        s_max = s_max or tokens.shape[1]
        x = self._embed(params, tokens, dtype)
        x, caches = stack_prefill(params["stack"], x, self.cfg, self.rt, s_max)
        x = apply_norm(self.cfg.norm, params["final_norm"], x[:, -1:])
        caches = shard_stack_cache(caches, self.cfg)
        return self.logits(params, x), caches

    def decode_step(self, params, token, caches, idx):
        """token: (B,1) int32; idx: (B,) positions. Returns (logits, caches)."""
        dtype = self.rt.dtype
        x = self._embed(params, token, dtype)
        x, caches = stack_decode(params["stack"], x, caches, idx, self.cfg,
                                 self.rt)
        x = apply_norm(self.cfg.norm, params["final_norm"], x)
        caches = shard_stack_cache(caches, self.cfg)
        return self.logits(params, x), caches

    def init_cache(self, batch: int, s_max: int):
        return init_stack_cache(self.cfg, batch, s_max, self.rt.dtype)

    # ----- paged serving (docs/serving.md) -----
    def init_pools(self, num_blocks: int, block_size: int):
        return init_stack_pools(self.cfg, num_blocks, block_size,
                                self.rt.dtype)

    def serve_step(self, params, tokens, pools, view):
        """One mixed prefill/decode step against paged KV pools.
        tokens: (B, S_step) int32 (0 at padding positions); ``view`` is the
        :class:`repro.models.attention.KVView` seam. Returns (per-row logits
        at each row's last valid position, (B, 1, V), and the new pools)."""
        dtype = self.rt.dtype
        x = self._embed(params, tokens, dtype)
        x, pools = stack_step(params["stack"], x, pools, view, self.cfg,
                              self.rt)
        B = x.shape[0]
        x_last = x[jnp.arange(B), view.last][:, None, :]
        x_last = apply_norm(self.cfg.norm, params["final_norm"], x_last)
        pools = shard_stack_pools(pools, self.cfg)
        return self.logits(params, x_last), pools
