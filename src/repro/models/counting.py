"""Parameter counting via ``jax.eval_shape`` over the real init functions —
exact by construction, no allocation (works for arctic-480b's ~0.5T params).

``active_only=True`` scales MoE expert tensors by top_k/num_experts for the
MODEL_FLOPS = 6·N_active·D roofline convention.
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax


@lru_cache(maxsize=None)
def _count(cfg, active_only: bool) -> int:
    # late imports to avoid config <-> model import cycles
    from repro.models.api import build_model
    from repro.runtime import Runtime

    model = build_model(cfg, Runtime())
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    moe_frac = 1.0
    if cfg.moe is not None and active_only:
        moe_frac = cfg.moe.top_k / cfg.moe.num_experts

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = math.prod(leaf.shape)
        keys = [str(getattr(p, "key", getattr(p, "name", "")))
                for p in path]
        is_expert = any(k in ("w_up", "w_down", "w_gate") for k in keys) and \
            any(k == "ffn" for k in keys) and cfg.moe is not None and \
            not any(k == "dense" for k in keys)
        total += int(n * (moe_frac if is_expert else 1.0))
    return total


def arch_param_count(cfg, active_only: bool = False) -> int:
    return _count(cfg, active_only)


def attention_core_flops(cfg, batch: int, seq: int) -> float:
    """FLOPs of one block's attention core (QK^T logits + softmax·V), the
    planner ``comp_hints`` source: 2 matmuls of 2·B·H·S²·dh each, halved by
    the causal mask → 2·B·H·S²·dh. Rope/softmax/reshape are dropped (they
    are O(B·H·S·dh), two orders below the S² terms at planner scales)."""
    return 2.0 * batch * cfg.num_heads * float(seq) * seq * \
        cfg.resolved_head_dim


def expert_ffn_flops(cfg, batch: int, seq: int) -> float:
    """FLOPs of one MoE block's routed expert compute (the ``b{i}.eout``
    ``a2a_ffn`` node), the second planner ``comp_hints`` source: the
    dispatch buffers carry ``E·cap`` padded rows with
    ``cap = B·S·top_k·capacity_factor / E``, and every row runs the
    up[+gate]+down expert GEMMs at 2·d·d_ff each. Router and
    dispatch/combine einsums are dropped (O(T·E·cap), below the d·d_ff
    terms at planner scales). Returns 0 for dense configs."""
    m = cfg.moe
    if m is None:
        return 0.0
    from repro.models.layers import gated

    rows = batch * float(seq) * m.top_k * m.capacity_factor
    n_gemms = 3 if gated(cfg.act) else 2
    return rows * n_gemms * 2.0 * cfg.d_model * cfg.d_ff
