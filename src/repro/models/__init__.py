from repro.models.api import build_model
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM
from repro.models.vlm import VLM

__all__ = ["build_model", "LM", "EncDecLM", "VLM"]
