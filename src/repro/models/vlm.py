"""Prefix-LM VLM (paligemma-3b). The SigLIP vision tower is a STUB per the
assignment: ``input_specs()`` supplies precomputed patch embeddings
``(B, num_prefix_tokens, vision_width)``; this module owns only the
projection into the LM width and the prefix-LM masking (bidirectional
attention among image-prefix tokens)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, dense_init
from repro.models.transformer import (
    LM,
    chunked_ce_loss,
    init_stack_cache,
    shard_stack_cache,
    stack_decode,
    stack_forward,
    stack_prefill,
)
from repro.runtime import Runtime

Params = Dict[str, Any]


class VLM:
    """Image-prefix + text decoder. Decode reuses the LM machinery with the
    image prefix living in the KV cache after prefill."""

    def __init__(self, cfg: ArchConfig, rt: Runtime = Runtime()):
        assert cfg.num_prefix_tokens > 0
        self.cfg = cfg
        self.rt = rt
        self.lm = LM(cfg, rt)

    def init(self, key) -> Params:
        k1, k2 = jax.random.split(key)
        p = self.lm.init(k1)
        p["vision_proj"] = dense_init(
            k2, (self.cfg.vision_width, self.cfg.d_model),
            dtype=self.rt.pdtype)
        return p

    def _embed_all(self, params, patch_embed, tokens):
        dtype = self.rt.dtype
        img = patch_embed.astype(dtype) @ params["vision_proj"].astype(dtype)
        txt = params["embed"].astype(dtype)[tokens]
        x = jnp.concatenate([img, txt], axis=1)
        return sharding.shard(x, sharding.BATCH_AXES, None, None)

    def loss(self, params, batch) -> jnp.ndarray:
        """batch: patch_embed (B,P,Wv), tokens (B,S), labels (B,S), mask."""
        cfg, rt = self.cfg, self.rt
        tokens, labels = batch["tokens"], batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        P = cfg.num_prefix_tokens
        x = self._embed_all(params, batch["patch_embed"], tokens)
        x, aux = stack_forward(params["stack"], x, cfg, rt, prefix_len=P)
        x = apply_norm(cfg.norm, params["final_norm"], x[:, P:])
        head, tied = self.lm._head(params)
        return chunked_ce_loss(x, head, labels, mask, cfg, rt, tied) + 0.01 * aux

    def prefill(self, params, batch, s_max: Optional[int] = None):
        cfg, rt = self.cfg, self.rt
        tokens = batch["tokens"]
        P = cfg.num_prefix_tokens
        s_max = s_max or (P + tokens.shape[1])
        x = self._embed_all(params, batch["patch_embed"], tokens)
        x, caches = stack_prefill(params["stack"], x, cfg, rt, s_max)
        x = apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
        caches = shard_stack_cache(caches, cfg)
        return self.lm.logits(params, x), caches

    def decode_step(self, params, token, caches, idx):
        """idx counts absolute position (image prefix included)."""
        return self.lm.decode_step(params, token, caches, idx)

    def init_cache(self, batch: int, s_max: int):
        return init_stack_cache(self.cfg, batch, s_max, self.rt.dtype)
