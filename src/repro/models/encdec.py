"""Encoder-decoder LM (whisper-tiny). The audio conv frontend is a STUB per
the assignment: ``input_specs()`` supplies precomputed frame embeddings
``(B, T_enc, d_model)``. Backbone only: encoder self-attention is
non-causal; the decoder adds causal self-attention (cached at decode) and
cross-attention whose K/V are computed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.layers import apply_norm, embed_init, init_norm
from repro.models.transformer import chunked_ce_loss
from repro.runtime import Runtime

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def init_enc_block(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
        "ffn": ffn_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def enc_block_forward(params, x, cfg: ArchConfig):
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    h = apply_norm(cfg.norm, params["norm1"], x)
    dtype = x.dtype
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (h @ params["attn"]["wq"].astype(dtype)).reshape(B, T, H, dh)
    k = (h @ params["attn"]["wk"].astype(dtype)).reshape(B, T, Hkv, dh)
    v = (h @ params["attn"]["wv"].astype(dtype)).reshape(B, T, Hkv, dh)
    o = attn.attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=False)
    x = x + o.reshape(B, T, -1) @ params["attn"]["wo"].astype(dtype)
    h = apply_norm(cfg.norm, params["norm2"], x)
    x = x + ffn_mod.mlp_forward(params["ffn"], h, cfg.act)
    return sharding.shard(x, sharding.BATCH_AXES, None, None)


# ---------------------------------------------------------------------------
# Decoder block = causal self-attn + cross-attn + FFN
# ---------------------------------------------------------------------------


def init_dec_block(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
        "self": attn.init_attention(k1, cfg, dtype),
        "norm_x": init_norm(cfg.norm, cfg.d_model, dtype),
        "cross": attn.init_cross_attention(k2, cfg, dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
        "ffn": ffn_mod.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def dec_block_forward(params, x, cross_kv, cfg: ArchConfig):
    h = apply_norm(cfg.norm, params["norm1"], x)
    x = x + attn.attention_forward(params["self"], h, cfg)
    h = apply_norm(cfg.norm, params["norm_x"], x)
    x = x + attn.cross_attention(params["cross"], h, cross_kv, cfg)
    h = apply_norm(cfg.norm, params["norm2"], x)
    x = x + ffn_mod.mlp_forward(params["ffn"], h, cfg.act)
    return sharding.shard(x, sharding.BATCH_AXES, None, None)


def dec_block_prefill(params, x, cross_kv, cfg: ArchConfig, s_max: int):
    h = apply_norm(cfg.norm, params["norm1"], x)
    mixed, cache = attn.attention_prefill(params["self"], h, cfg, s_max=s_max)
    x = x + mixed
    h = apply_norm(cfg.norm, params["norm_x"], x)
    x = x + attn.cross_attention(params["cross"], h, cross_kv, cfg)
    h = apply_norm(cfg.norm, params["norm2"], x)
    x = x + ffn_mod.mlp_forward(params["ffn"], h, cfg.act)
    return sharding.shard(x, sharding.BATCH_AXES, None, None), cache


def dec_block_decode(params, x, cache, cross_kv, idx, cfg: ArchConfig):
    h = apply_norm(cfg.norm, params["norm1"], x)
    mixed, cache = attn.attention_decode(params["self"], h, cache, idx, cfg)
    x = x + mixed
    h = apply_norm(cfg.norm, params["norm_x"], x)
    x = x + attn.cross_attention(params["cross"], h, cross_kv, cfg)
    h = apply_norm(cfg.norm, params["norm2"], x)
    x = x + ffn_mod.mlp_forward(params["ffn"], h, cfg.act)
    return x, cache


# ---------------------------------------------------------------------------
# EncDecLM
# ---------------------------------------------------------------------------


class EncDecLM:
    def __init__(self, cfg: ArchConfig, rt: Runtime = Runtime()):
        assert cfg.encoder is not None
        self.cfg = cfg
        self.rt = rt

    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.rt.pdtype
        k1, k2, k3 = jax.random.split(key, 3)
        enc_blocks = jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(
            jax.random.split(k1, cfg.encoder.num_layers))
        dec_blocks = jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(
            jax.random.split(k2, cfg.num_layers))
        return {
            "embed": embed_init(k3, (cfg.vocab_size, cfg.d_model), dtype),
            "enc_blocks": enc_blocks,
            "enc_norm": init_norm(cfg.norm, cfg.d_model, dtype),
            "dec_blocks": dec_blocks,
            "dec_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }

    # ----- encoder -----
    def encode(self, params, src_embed):
        cfg = self.cfg
        x = src_embed.astype(self.rt.dtype)
        x = sharding.shard(x, sharding.BATCH_AXES, None, None)

        def body(x, p):
            return enc_block_forward(p, x, cfg), None

        if self.rt.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return apply_norm(cfg.norm, params["enc_norm"], x)

    def _cross_kvs(self, params, enc_out):
        cfg = self.cfg

        def body(_, p):
            return None, attn.cross_attention_kv(p["cross"], enc_out, cfg)

        _, kvs = jax.lax.scan(body, None, params["dec_blocks"])
        return kvs  # stacked over layers

    # ----- training -----
    def loss(self, params, batch) -> jnp.ndarray:
        cfg, rt = self.cfg, self.rt
        tokens, labels = batch["tokens"], batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        enc_out = self.encode(params, batch["src_embed"])
        cross_kvs = self._cross_kvs(params, enc_out)
        x = params["embed"].astype(rt.dtype)[tokens]
        x = sharding.shard(x, sharding.BATCH_AXES, None, None)

        def body(x, inp):
            p, kv = inp
            return dec_block_forward(p, x, kv, cfg), None

        if rt.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["dec_blocks"], cross_kvs))
        x = apply_norm(cfg.norm, params["dec_norm"], x)
        return chunked_ce_loss(x, params["embed"], labels, mask, cfg, rt,
                               tied=True)

    # ----- serving -----
    def prefill(self, params, batch, s_max: Optional[int] = None):
        cfg, rt = self.cfg, self.rt
        tokens = batch["tokens"]
        s_max = s_max or tokens.shape[1]
        enc_out = self.encode(params, batch["src_embed"])
        cross_kvs = self._cross_kvs(params, enc_out)
        x = params["embed"].astype(rt.dtype)[tokens]

        def body(x, inp):
            p, kv = inp
            x, cache = dec_block_prefill(p, x, kv, cfg, s_max)
            return x, cache

        x, self_caches = jax.lax.scan(body, x, (params["dec_blocks"],
                                                cross_kvs))
        x = apply_norm(cfg.norm, params["dec_norm"], x[:, -1:])
        logits = x @ params["embed"].astype(rt.dtype).T
        caches = {"self": self_caches, "cross": cross_kvs}
        return logits.astype(jnp.float32), caches

    def decode_step(self, params, token, caches, idx):
        cfg, rt = self.cfg, self.rt
        x = params["embed"].astype(rt.dtype)[token]

        def body(x, inp):
            p, cache, kv = inp
            x, cache = dec_block_decode(p, x, cache, kv, idx, cfg)
            return x, cache

        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], caches["self"], caches["cross"]))
        x = apply_norm(cfg.norm, params["dec_norm"], x)
        logits = x @ params["embed"].astype(rt.dtype).T
        return logits.astype(jnp.float32), {"self": new_self,
                                            "cross": caches["cross"]}

    def init_cache(self, batch: int, s_max: int):
        cfg = self.cfg
        one = attn.init_dense_cache(cfg, batch, s_max, self.rt.dtype)
        self_caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one)
        T = cfg.encoder.max_source_len
        Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, T, Hkv, dh), self.rt.dtype),
            "v": jnp.zeros((cfg.num_layers, batch, T, Hkv, dh), self.rt.dtype),
        }
        return {"self": self_caches, "cross": cross}
