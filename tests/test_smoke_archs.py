"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED config of the same family and runs
one forward/loss (train step math) plus prefill + decode on CPU, asserting
output shapes and absence of NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.runtime import SMOKE

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 4)
    b = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.is_enc_dec:
        b["src_embed"] = jax.random.normal(
            ks[2], (batch, cfg.encoder.max_source_len, cfg.d_model))
    if cfg.num_prefix_tokens:
        b["patch_embed"] = jax.random.normal(
            ks[3], (batch, cfg.num_prefix_tokens, cfg.vision_width))
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_loss_finite(name):
    cfg = get_arch(name).smoke()
    model = build_model(cfg, SMOKE)
    key = jax.random.key(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.key(1))
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    # a random model should sit near ln(vocab)
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_grad_step_finite(name):
    cfg = get_arch(name).smoke()
    model = build_model(cfg, SMOKE)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode(name):
    cfg = get_arch(name).smoke()
    model = build_model(cfg, SMOKE)
    params = model.init(jax.random.key(0))
    batch, seq = 2, 8
    b = make_batch(cfg, jax.random.key(1), batch=batch, seq=seq)
    s_max = seq + 4 + cfg.num_prefix_tokens
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, s_max=s_max))(params, b)
    assert logits.shape == (batch, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    idx = jnp.full((batch,), seq + cfg.num_prefix_tokens, jnp.int32)
    step = jax.jit(model.decode_step)
    for t in range(2):
        logits2, caches = step(params, tok, caches, idx + t)
        assert logits2.shape == (batch, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
        tok = jnp.argmax(logits2[:, -1], -1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_positive(name):
    cfg = get_arch(name)
    n = cfg.param_count()
    na = cfg.active_param_count()
    assert n > 0 and 0 < na <= n
