"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-1)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128), (256, 512, 128), (64, 384, 96), (32, 32, 32),
    (512, 128, 256), (128, 1024, 64),
])
def test_matmul_sweep(M, K, N, dtype):
    a = _rand(jax.random.key(0), (M, K), dtype)
    b = _rand(jax.random.key(1), (K, N), dtype)
    got = ops.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 64, 256),
                                      (256, 256, 512)])
def test_matmul_block_shapes(bm, bn, bk):
    a = _rand(jax.random.key(2), (256, 512), jnp.float32)
    b = _rand(jax.random.key(3), (512, 128), jnp.float32)
    got = ops.matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               **TOL[jnp.float32])


@given(m=st.sampled_from([16, 64, 128]), k=st.sampled_from([32, 128, 320]),
       n=st.sampled_from([16, 48, 128]))
@settings(max_examples=12, deadline=None)
def test_matmul_property(m, k, n):
    a = _rand(jax.random.key(m * k), (m, k), jnp.float32)
    b = _rand(jax.random.key(k * n + 1), (k, n), jnp.float32)
    got = ops.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               **TOL[jnp.float32])


# ---------------------------------------------------------------------------
# fused matmul + rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (64, 512, 384),
                                   (256, 128, 64)])
def test_matmul_rmsnorm_sweep(M, K, N, dtype):
    a = _rand(jax.random.key(0), (M, K), dtype)
    b = _rand(jax.random.key(1), (K, N), dtype)
    scale = _rand(jax.random.key(2), (N,), jnp.float32) * 0.1
    got = ops.matmul_rmsnorm(a, b, scale)
    want = ref.matmul_rmsnorm_ref(a, b, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_matmul_rmsnorm_matches_model_norm():
    """The kernel's epilogue must equal the model's apply_norm(rmsnorm)."""
    from repro.models.layers import apply_norm
    a = _rand(jax.random.key(0), (32, 64), jnp.float32)
    b = _rand(jax.random.key(1), (64, 48), jnp.float32)
    scale = _rand(jax.random.key(2), (48,), jnp.float32) * 0.1
    got = ops.matmul_rmsnorm(a, b, scale)
    want = apply_norm("rmsnorm", {"scale": scale}, a @ b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,d", [(2, 128, 64), (4, 256, 32), (1, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(BH, S, d, dtype, causal):
    ks = jax.random.split(jax.random.key(S + d), 3)
    q = _rand(ks[0], (BH, S, d), dtype)
    k = _rand(ks[1], (BH, S, d), dtype)
    v = _rand(ks[2], (BH, S, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, bq=64, bkv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_matches_model_attention_core():
    """Kernel vs the model zoo's chunked attention core (same oracle)."""
    from repro.models.attention import attention_core
    B, S, H, d = 2, 128, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, S, H, d), jnp.float32)
    k = _rand(ks[1], (B, S, H, d), jnp.float32)
    v = _rand(ks[2], (B, S, H, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    want = attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    got = ops.flash_attention(qf, kf, vf, causal=True, bq=32, bkv=32)
    got = got.reshape(B, H, S, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-4)


@given(s=st.sampled_from([64, 128, 320]), d=st.sampled_from([32, 64]),
       bq=st.sampled_from([32, 64]), bkv=st.sampled_from([32, 64]))
@settings(max_examples=10, deadline=None)
def test_flash_block_shape_property(s, d, bq, bkv):
    ks = jax.random.split(jax.random.key(s * d + bq), 3)
    q, k, v = (_rand(kk, (1, s, d), jnp.float32) for kk in ks)
    got = ops.flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-4)
