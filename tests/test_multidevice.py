"""Runs the 8-virtual-device correctness suite in a subprocess so the main
pytest process keeps exactly one device (the dry-run owns device-count
overrides; see the assignment's XLA_FLAGS note)."""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def test_main_process_single_device():
    assert len(jax.devices()) == 1


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(HERE / "multidev_checks.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "multi-device checks failed"
    assert "ALL OK" in proc.stdout
