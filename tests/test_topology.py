"""Hierarchical 2D-mesh TP (docs/topology.md) — the ISSUE-9 pins.

Tier-1 (single-device) layer: property-based invariants of the
``coordination.plan`` chunk scheduler across both fabric tiers, the
``HWSpec.inter_tier()`` / per-axis planning regression (the 2D-mesh
microbatch and chunk plans must be computed against the inter-node tier,
not the flat intra-node ring), and the composite-axis sharding helpers.

The 8-virtual-device parity sweep (flat ring ≡ 2D mesh for every
factorization × backend × shape, grouped-EP MoE, full-model fwd+grads)
lives in ``tests/topo_checks.py`` and runs as a subprocess under the
``multidev`` marker — the main pytest process keeps exactly one device.
"""
import os
import pathlib
import subprocess
import sys

import pytest

from _hypothesis_compat import given, st
from repro import sharding
from repro.core import coordination
from repro.hw import V5E

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent

INTER = V5E.inter_tier()


# ---------------------------------------------------------------------------
# coordination.plan invariants (property layer)
# ---------------------------------------------------------------------------


@given(payload=st.sampled_from([1e3, 1e5, 1e7, 1e9]),
       ring=st.integers(2, 8),
       bidirectional=st.booleans(),
       inter=st.booleans())
def test_plan_chunk_bounds(payload, ring, bidirectional, inter):
    """chunks >= 1 always; chunks <= max_chunks unless the staging budget
    forced past the cap — and then the plan must say so (over_cap)."""
    hw = INTER if inter else V5E
    p = coordination.plan(payload, ring, bidirectional=bidirectional, hw=hw)
    assert p.num_chunks >= 1
    assert p.num_chunks <= 64 or p.over_cap
    assert p.staging_bytes >= 0 and p.total_comm >= 0.0


@given(ring=st.integers(2, 8),
       bidirectional=st.booleans(),
       inter=st.booleans())
def test_plan_monotone_in_payload(ring, bidirectional, inter):
    """At compute_time=0 a larger payload never plans FEWER chunks: both
    the latency bound and the staging bound scale up with the shard."""
    hw = INTER if inter else V5E
    payloads = [1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9]
    chunks = [coordination.plan(p, ring, bidirectional=bidirectional,
                                hw=hw).num_chunks for p in payloads]
    assert chunks == sorted(chunks), chunks


@given(payload=st.sampled_from([1e5, 1e7, 1e9]),
       ring=st.integers(2, 8))
def test_plan_inter_tier_plans_coarser(payload, ring):
    """The slow high-latency inter-node tier must never chunk finer than
    the intra-node ring for the same payload: the latency bound
    chunk >= alpha*beta*(1/maxfrac - 1) grows with alpha."""
    flat = coordination.plan(payload, ring, hw=V5E)
    inter = coordination.plan(payload, ring, hw=INTER)
    assert inter.num_chunks <= flat.num_chunks


# ---------------------------------------------------------------------------
# HWSpec inter-node tier + per-axis planning regression (ISSUE-9 fix)
# ---------------------------------------------------------------------------


def test_inter_tier_hwspec():
    assert V5E.dcn_bw < V5E.ici_bw
    assert V5E.dcn_latency > V5E.hop_latency
    assert INTER.ici_bw == V5E.dcn_bw
    assert INTER.hop_latency == V5E.dcn_latency
    # compute/memory side unchanged — only the fabric tier swaps
    assert INTER.peak_flops == V5E.peak_flops


def test_two_tier_hwspec_plan_regression():
    """A scaled-down two-tier HWSpec: the chunk plan for the SAME payload
    must differ between tiers (the bug this pins: feeding the flat-ring
    fabric to the planner on a 2D mesh silently over-chunks the slow
    tier)."""
    import dataclasses

    hw = dataclasses.replace(V5E, ici_bw=100e9, hop_latency=1e-6,
                             dcn_bw=10e9, dcn_latency=50e-6)
    payload = 64 * 1024 * 1024
    flat = coordination.plan(payload, 4, hw=hw)
    inter = coordination.plan(payload, 4, hw=hw.inter_tier())
    assert inter.num_chunks < flat.num_chunks, (inter, flat)


def test_plan_microbatches_inter_tier_splits_less():
    """plan_microbatches on the inter-node tier: the latency floor is ~50x
    higher, so the auto split must be no larger than the intra-node one
    (and strictly smaller at a payload near the floor)."""
    payload = 4 * 1024 * 1024
    mb_flat = coordination.plan_microbatches(8, payload, 4, hw=V5E)
    mb_inter = coordination.plan_microbatches(8, payload, 4, hw=INTER)
    assert mb_inter <= mb_flat
    assert coordination.plan_microbatches(8, 256 * 1024, 4, hw=INTER) == 1


def test_planned_chunks_cache_keyed_by_hw():
    """The cais auto-chunk memo must key on the hw tier: the same payload
    over the same ring plans differently per tier."""
    from repro.core.backends import _planned_chunks

    payload = 64 * 1024 * 1024
    flat = _planned_chunks(payload, 8, True, V5E)
    inter = _planned_chunks(payload, 8, True, INTER)
    assert flat != inter, (flat, inter)


# ---------------------------------------------------------------------------
# composite-axis sharding helpers (mesh-free paths)
# ---------------------------------------------------------------------------


def test_tp_axes_and_size_defaults():
    assert sharding.tp_axes(None) == sharding.MODEL_AXIS
    assert sharding.tp_size(None) == 1
    assert sharding.TP_AXES_2D == (sharding.TP_IN_AXIS, sharding.TP_OUT_AXIS)


def test_composite_flat_index_layout():
    """Layout contract (docs/topology.md): the composite ("tp_in",
    "tp_out") entry is tp_in-MAJOR — flattened shard s = i_in * O + i_out.
    Pin the pure-python mirror of shard_map_axis_index so the in-graph
    GQA head slicing and the PartitionSpec layout cannot drift apart."""
    I, O = 2, 4
    seen = []
    for i_in in range(I):
        for i_out in range(O):
            seen.append(i_in * O + i_out)
    assert seen == list(range(I * O))


# ---------------------------------------------------------------------------
# 8-virtual-device parity sweep (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.multidev
def test_topology_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(HERE / "topo_checks.py")],
        capture_output=True, text=True, env=env, timeout=2400,
        cwd=str(REPO))
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "2D-topology checks failed"
    assert "ALL OK" in proc.stdout
