"""Paged-KV continuous-batching serving tests (docs/serving.md):
block allocator / prefix cache units, scheduler admission + token budget,
greedy-decode token parity paged vs dense (full prefill, chunked prefill,
prefix reuse; dense/GQA/SWA/MoE), deterministic fold_in sampling replay,
and load-generator determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.runtime import SMOKE
from repro.serve import (BlockAllocator, DenseEngine, Engine, LoadSpec,
                         Request, Scheduler, ServeConfig, blocks_needed,
                         generate, paged_supported)


def setup(arch):
    cfg = get_arch(arch).smoke()
    model = build_model(cfg, SMOKE)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def mixed_requests(cfg, n=6, max_new=4):
    return [Request(rid=i, prompt=np.arange(1, 6 + (i % 2)) % cfg.vocab_size,
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# allocator units
# ---------------------------------------------------------------------------


def test_blocks_needed():
    assert blocks_needed(5, 4, 4) == 2      # positions 0..7
    assert blocks_needed(8, 1, 4) == 2      # prompt only: 0..7
    assert blocks_needed(1, 1, 4) == 1


def test_allocator_free_list_and_refcounts():
    a = BlockAllocator(4, 8)
    ids = a.alloc(3)
    assert ids is not None and len(set(ids)) == 3
    assert a.num_free() == 1 and a.utilization() == 0.75
    assert a.alloc(2) is None               # over-subscribe -> defer
    a.release(ids)
    assert a.num_free() == 4
    with pytest.raises(AssertionError):
        a.release(ids)                      # double free is a bug


def test_prefix_cache_reuse_and_eviction():
    a = BlockAllocator(4, block_size=4)
    prompt = np.arange(1, 10, dtype=np.int32)          # 9 tokens, 2 full blocks
    ids = a.alloc(3)
    a.register_prefix(prompt, ids)
    # same prompt: both full blocks reused, never the partial third
    got, reuse = a.match_prefix(prompt)
    assert got == ids[:2] and reuse == 8
    a.release(got)
    # a prompt sharing only the first block matches the nested entry
    other = np.concatenate([prompt[:4], np.asarray([99, 98], np.int32)])
    got1, reuse1 = a.match_prefix(other)
    assert got1 == ids[:1] and reuse1 == 4
    a.release(got1)
    assert a.prefix_hits == 2
    # reuse never covers the whole prompt (>= 1 token must be fed)
    got2, reuse2 = a.match_prefix(prompt[:8])
    assert reuse2 == 4 and got2 == ids[:1]
    a.release(got2)
    # cache-held blocks are evicted LRU when allocation needs them
    a.release(ids)
    assert a.num_free() == 2                # partial block + the unallocated
    assert a.utilization() == 0.5           # 2 blocks resident, cache-only
    fresh = a.alloc(3)                      # needs eviction: frees LRU entry
    assert fresh is not None and a.num_free() == 0
    more = a.alloc(1)                       # evicts the last cached entry
    assert more is not None
    assert a.match_prefix(prompt) == ([], 0)    # cache fully evicted


# ---------------------------------------------------------------------------
# scheduler units
# ---------------------------------------------------------------------------


def _sched(num_blocks=8, block_size=4, max_batch=4, prefill_chunk=4,
           token_budget=8, max_active=4):
    return Scheduler(BlockAllocator(num_blocks, block_size),
                     max_batch=max_batch, prefill_chunk=prefill_chunk,
                     token_budget=token_budget, max_active=max_active)


def test_scheduler_admission_reserves_blocks():
    s = _sched(num_blocks=4, max_active=4)
    # each request needs 2 blocks (5 prompt + 3 new = positions 0..6)
    rs = [Request(rid=i, prompt=np.arange(1, 6), max_new_tokens=3)
          for i in range(3)]
    s.submit(rs)
    s.admit(now=0.0)
    assert len(s.active) == 2 and len(s.waiting) == 1   # 4 blocks -> 2 admits
    rows = s.next_batch()
    assert all(r.is_prefill for r in rows) and len(rows) == 2


def test_scheduler_token_budget_chunks_prefill():
    s = _sched(token_budget=6, prefill_chunk=4)
    s.submit([Request(rid=0, prompt=np.arange(1, 11), max_new_tokens=2),
              Request(rid=1, prompt=np.arange(1, 11), max_new_tokens=2)])
    s.admit(0.0)
    rows = s.next_batch()
    # 10-token prompts, chunk 4, budget 6: one full chunk + one clipped
    assert [len(r.tokens) for r in rows] == [4, 2]
    assert not any(r.sample for r in rows)
    assert list(rows[0].positions) == [0, 1, 2, 3]


def test_scheduler_mixed_decode_and_prefill():
    s = _sched(token_budget=4, prefill_chunk=3)
    a = Request(rid=0, prompt=np.arange(1, 4), max_new_tokens=3)
    s.submit([a])
    s.admit(0.0)
    (row,) = s.next_batch()
    assert row.sample                        # chunk reaches prompt end
    s.advance(0, len(row.tokens), 42)
    b = Request(rid=1, prompt=np.arange(1, 4), max_new_tokens=2)
    s.submit([b])
    s.admit(0.0)
    rows = s.next_batch()
    kinds = [(r.rid, r.is_prefill) for r in rows]
    assert kinds == [(0, False), (1, True)]   # decode first, prefill rides
    assert list(rows[0].tokens) == [42]
    assert rows[0].context_len == 4 and list(rows[0].positions) == [3]


def test_scheduler_retires_and_frees_blocks():
    s = _sched(num_blocks=2, max_active=1)
    s.submit([Request(rid=0, prompt=np.arange(1, 4), max_new_tokens=1),
              Request(rid=1, prompt=np.arange(1, 4), max_new_tokens=1)])
    s.admit(0.0)
    (row,) = s.next_batch()
    s.advance(0, len(row.tokens), 5)
    assert s._by_rid.get(0) is None          # retired at budget
    s.admit(0.0)
    assert [q.rid for q in s.waiting] == [] and len(s.active) == 1


# ---------------------------------------------------------------------------
# greedy-decode token parity: paged continuous batching == dense engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma3-1b"])
def test_paged_dense_greedy_parity(arch):
    cfg, model, params = setup(arch)
    sc = ServeConfig(max_batch=4, s_max=32)
    pag = Engine(model, params, cfg, SMOKE, sc)
    den = DenseEngine(model, params, cfg, SMOKE, sc)
    assert pag._paged
    rp = pag.run(mixed_requests(cfg))
    rd = den.run(mixed_requests(cfg))
    assert [r.out_tokens for r in rp] == [r.out_tokens for r in rd]
    assert all(r.done and len(r.out_tokens) == 4 for r in rp)


def test_paged_dense_parity_moe_shape_matched():
    # capacity-bounded GShard routing couples tokens across the flattened
    # batch, so MoE parity is pinned at matching batch shapes: B=1 and a
    # full-prompt prefill chunk make paged and dense token tensors identical
    cfg, model, params = setup("mixtral-8x7b")
    sc = ServeConfig(max_batch=1, s_max=32, prefill_chunk=5)
    pag = Engine(model, params, cfg, SMOKE, sc)
    den = DenseEngine(model, params, cfg, SMOKE, sc)
    mk = lambda: [Request(rid=i, prompt=np.arange(1, 6) % cfg.vocab_size,
                          max_new_tokens=4) for i in range(2)]
    rp, rd = pag.run(mk()), den.run(mk())
    assert [r.out_tokens for r in rp] == [r.out_tokens for r in rd]


def test_chunked_prefill_parity():
    cfg, model, params = setup("deepseek-7b")
    prompt = (np.arange(1, 20) % cfg.vocab_size).astype(np.int32)
    den = DenseEngine(model, params, cfg, SMOKE,
                      ServeConfig(max_batch=1, s_max=64))
    ref = den.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    for chunk in (4, 7):                     # 19 % 4 != 0, 19 % 7 != 0
        pag = Engine(model, params, cfg, SMOKE,
                     ServeConfig(max_batch=1, s_max=64, block_size=4,
                                 prefill_chunk=chunk))
        out = pag.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
        assert out[0].out_tokens == ref[0].out_tokens, f"chunk={chunk}"


def test_prefix_reuse_parity_and_savings():
    cfg, model, params = setup("deepseek-7b")
    prompt = (np.arange(1, 20) % cfg.vocab_size).astype(np.int32)
    sc = ServeConfig(max_batch=2, s_max=64, block_size=4, max_active=1)
    pag = Engine(model, params, cfg, SMOKE, sc)
    rs = [Request(rid=i, prompt=prompt, max_new_tokens=3) for i in range(2)]
    pag.run(rs)
    assert pag.last_report["prefix_hits"] >= 1
    assert rs[0].out_tokens == rs[1].out_tokens
    den = DenseEngine(model, params, cfg, SMOKE, sc)
    rd = den.run([Request(rid=0, prompt=prompt, max_new_tokens=3)])
    assert rs[0].out_tokens == rd[0].out_tokens


def test_paged_fallback_archs():
    cfg, model, params = setup("mamba2-130m")     # ssm mixer: dense path
    assert not paged_supported(model, cfg)
    eng = Engine(model, params, cfg, SMOKE, ServeConfig(max_batch=2, s_max=32))
    assert not eng._paged
    rs = eng.run([Request(rid=0, prompt=np.arange(1, 6), max_new_tokens=3)])
    assert rs[0].done and len(rs[0].out_tokens) == 3


# ---------------------------------------------------------------------------
# satellite regressions: config defaults + deterministic sampling
# ---------------------------------------------------------------------------


def test_serve_config_not_shared_mutable_default():
    import dataclasses

    # the old bug: `serve_cfg: ServeConfig = ServeConfig()` evaluated once at
    # def time, sharing one mutable instance across engines
    import inspect

    from repro.serve import engine as engine_mod
    for cls in (Engine, DenseEngine):
        sig = inspect.signature(cls.__init__)
        assert sig.parameters["serve_cfg"].default is None, cls
    assert dataclasses.fields(ServeConfig)[0].name == "max_batch"
    with pytest.raises(dataclasses.FrozenInstanceError):
        dataclasses.replace(ServeConfig()), setattr(ServeConfig(), "s_max", 1)
    cfg, model, params = setup("deepseek-7b")
    e1 = Engine(model, params, cfg, SMOKE)
    e2 = Engine(model, params, cfg, SMOKE)
    assert e1.sc is not e2.sc
    assert engine_mod.ServeConfig is ServeConfig


def test_sampling_replayable_across_batch_composition():
    cfg, model, params = setup("deepseek-7b")
    prompt = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    mk = lambda rid: Request(rid=rid, prompt=prompt.copy(),
                             max_new_tokens=4, temperature=0.7)
    # solo run vs the same request batched with other traffic: fold_in keys
    # depend only on (seed, rid, token_index), so tokens must match exactly
    solo = Engine(model, params, cfg, SMOKE,
                  ServeConfig(max_batch=4, s_max=32))
    a = solo.run([mk(7)], key=123)
    others = [Request(rid=i, prompt=np.arange(1, 5 + i), max_new_tokens=2)
              for i in range(3)]
    b = solo.run([mk(7)] + others, key=123)
    assert a[0].out_tokens == b[0].out_tokens
    assert a[0].seed == 123 and b[0].seed == 123
    # and the seed is recorded from a PRNG key too
    c = solo.run([mk(7)], key=jax.random.key(123))
    assert c[0].seed is not None


def test_loadgen_deterministic_and_metrics():
    cfg, model, params = setup("deepseek-7b")
    spec = LoadSpec(kind="burst", num_requests=6, burst_size=3, gap_s=0.05,
                    prompt_len_min=3, prompt_len_max=6, max_new_tokens=3,
                    seed=11)
    a, b = generate(spec, cfg.vocab_size), generate(spec, cfg.vocab_size)
    assert all((x.prompt == y.prompt).all()
               and x.arrival_time == y.arrival_time for x, y in zip(a, b))
    pois = generate(LoadSpec(kind="poisson", num_requests=5, rate=100.0,
                             seed=2), cfg.vocab_size)
    assert pois[0].arrival_time == 0.0
    assert all(x.arrival_time <= y.arrival_time
               for x, y in zip(pois, pois[1:]))
    eng = Engine(model, params, cfg, SMOKE, ServeConfig(max_batch=4, s_max=32))
    eng.run(a, key=5)
    rep = eng.last_report
    for k in ("ttft_p50_ms", "ttft_p99_ms", "per_token_p50_ms",
              "per_token_p99_ms", "tokens_per_sec_per_device",
              "kv_block_utilization", "makespan_s"):
        assert k in rep and rep[k] >= 0.0, k
    assert rep["seed"] == 5.0
    assert rep["total_tokens"] == 6 * 3
    assert all(r.t_first_token is not None and len(r.token_times) == 3
               for r in a)
