"""Tier-1 wrapper around the docs checker CI runs as
``python -m tests.check_docs`` — README/docs code fences balanced, every
referenced repo path exists."""
from tests.check_docs import main


def test_docs_fences_and_paths():
    assert main() == 0
