"""repro.plan: lowering bridge, pairing/chunk/microbatch search, plan cache,
and calibration — the ISSUE-6 acceptance pins (device-free; the multi-device
numerics parity lives in tests/multidev_checks.py)."""
import os

import pytest

from repro.core import dataflow as df
from repro.core.perfsim import Fabric
from repro.plan import (CalibrationResult, PerfsimPlanner, PlanCache,
                        RATIO_TOLERANCE, calibrate, fabric_from_hw,
                        microbatch_value_shapes, policy_for_backend,
                        search_pairing, search_period, simulate)

FABRIC = Fabric(n=8)

BENCH_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_pr10.json")


def _pass2(g):
    return df.fuse_sublayer_chain(df.fuse_shared_gather(
        df.fuse_compute_aware(g)))


# ---------------------------------------------------------------------------
# lowering bridge
# ---------------------------------------------------------------------------


def test_lowering_positive_makespans():
    g = df.optimize(df.sublayer_graph())
    for backend in ("barrier", "cais"):
        m = simulate(g, FABRIC, policy_for_backend(backend))
        assert m > 0


def test_lowering_cais_beats_barrier_on_sublayer():
    """The whole point of the schedule: decomposed bidirectional rings
    overlap collective bytes under compute that barrier collectives expose."""
    g = df.optimize(df.sublayer_graph())
    m_cais = simulate(g, FABRIC, policy_for_backend("cais"))
    m_barrier = simulate(g, FABRIC, policy_for_backend("barrier"))
    assert m_cais < m_barrier


def test_lowering_scales_with_payload():
    g = df.optimize(df.sublayer_graph())
    policy = policy_for_backend("cais")
    small = simulate(g, FABRIC, policy,
                     value_shapes={"x": (2, 64, 256)},
                     weight_shapes={"w1": (256, 256), "w2": (256, 256),
                                    "scale": (256,)})
    large = simulate(g, FABRIC, policy,
                     value_shapes={"x": (8, 512, 1024)},
                     weight_shapes={"w1": (1024, 1024), "w2": (1024, 1024),
                                    "scale": (1024,)})
    assert large > small


# ---------------------------------------------------------------------------
# pairing search (ISSUE 6 acceptance: makespan ≤ greedy; ≥1 pairing differs
# from nearest-first on at least one test graph)
# ---------------------------------------------------------------------------


def test_search_not_worse_than_greedy_dual_sublayer():
    p = search_pairing(_pass2(df.dual_sublayer_graph()), fabric=FABRIC)
    assert p.makespan <= p.greedy_makespan + 1e-12


def test_search_not_worse_than_greedy_two_block_period():
    from repro.core import tp as tp_mod

    core = lambda q, k, v: q                               # noqa: E731
    base = tp_mod.dense_period_graph([core] * 2, has_gate=True, act="silu")
    p = search_period(base, fabric=FABRIC, backend="cais",
                      x_shape=(8, 256, 512),
                      weight_shapes=_period_weights(512, 1024, blocks=2),
                      mb_candidates=(1, 2))
    assert p.makespan <= p.greedy_makespan + 1e-12
    assert p.num_microbatches in (1, 2)


def _period_weights(d, d_ff, blocks):
    out = {}
    for i in range(blocks):
        p = f"b{i}."
        out.update({p + "scale1": (d,), p + "scale2": (d,),
                    p + "wq": (d, d), p + "wk": (d, d), p + "wv": (d, d),
                    p + "wo": (d, d), p + "w_up": (d, d_ff),
                    p + "w_gate": (d, d_ff), p + "w_down": (d_ff, d)})
    return out


def _three_chain_graph():
    """One large gemm_rs chain vs two ag_gemm chains: the topologically
    NEAR gather (agb) is small, the FAR one (agc) moves as many bytes as
    the rs chain. Nearest-first pairs (rsa, gb); balancing the two large
    complementary-direction transfers — (rsa, gc) — is strictly better."""
    return df.Graph(
        nodes=[
            df.Node("xa", "input"),
            df.Node("xb", "input"),
            df.Node("xc", "input"),
            df.Node("ga", "gemm_row", ("xa",), ("wa",)),
            df.Node("rsa", "reduce_scatter", ("ga",)),
            df.Node("agb", "allgather", ("xb",)),
            df.Node("gb", "gemm_col", ("agb",), ("wb",)),
            df.Node("agc", "allgather", ("xc",)),
            df.Node("gc", "gemm_col", ("agc",), ("wc",)),
        ],
        outputs=("rsa", "gb", "gc"),
    )


_THREE_CHAIN_SHAPES = dict(
    value_shapes={"xa": (8, 512, 4096), "xb": (8, 512, 128),
                  "xc": (8, 512, 4096)},
    weight_shapes={"wa": (4096, 4096), "wb": (128, 128),
                   "wc": (4096, 4096)})


def test_planner_pairing_differs_from_nearest_first():
    g2 = _pass2(_three_chain_graph())
    greedy = df.asymmetric_candidates(g2)[0]
    assert (greedy[0].name, greedy[1].name) == ("rsa", "gb")
    p = search_pairing(g2, fabric=FABRIC, **_THREE_CHAIN_SHAPES)
    assert ("rsa", "gc") in p.pairing, p.pairing
    assert p.makespan < p.greedy_makespan


def test_planner_object_applies_winning_pairing():
    g2 = _pass2(_three_chain_graph())
    planner = PerfsimPlanner(fabric=FABRIC, **_THREE_CHAIN_SHAPES)
    out = planner.pair(g2)
    names = [n.name for n in out.nodes if n.op == "overlap_asym"]
    assert names == ["rsa+gc"], names
    out.validate()


def test_optimize_planner_parity_reference_semantics():
    """optimize(planner=...) must preserve the math even when the pairing
    differs from greedy (single-device reference execution)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    g = _three_chain_graph()
    # tiny dims for execution; shapes that force the non-greedy pairing are
    # injected via the planner's value/weight shape overrides
    d_l, d_s = 16, 8
    planner = PerfsimPlanner(
        fabric=FABRIC,
        value_shapes={"xa": (2, 8, 4096), "xb": (2, 8, 128),
                      "xc": (2, 8, 4096)},
        weight_shapes={"wa": (4096, 4096), "wb": (128, 128),
                       "wc": (4096, 4096)})
    opt = df.optimize(g, planner=planner)
    assert any(n.op == "overlap_asym" for n in opt.nodes)
    ks = jax.random.split(jax.random.key(0), 6)
    vals = {"xa": jax.random.normal(ks[0], (2, 8, d_l)),
            "xb": jax.random.normal(ks[1], (2, 8, d_s)),
            "xc": jax.random.normal(ks[2], (2, 8, d_l))}
    w = {"wa": jax.random.normal(ks[3], (d_l, d_l)) * 0.1,
         "wb": jax.random.normal(ks[4], (d_s, d_s)) * 0.1,
         "wc": jax.random.normal(ks[5], (d_l, d_l)) * 0.1}
    a = df.execute(g, dict(vals), dict(w))
    b = df.execute(opt, dict(vals), dict(w))
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-5)


def test_barrier_backend_skips_chunk_sweep():
    p = search_pairing(_pass2(df.dual_sublayer_graph()), fabric=FABRIC,
                       backend="barrier")
    assert p.num_chunks is None


def test_microbatch_value_shapes():
    assert microbatch_value_shapes((8, 64, 32), 1) == {"x": (8, 64, 32)}
    assert microbatch_value_shapes((8, 64, 32), 4) == {
        f"mb{i}.x": (2, 64, 32) for i in range(4)}


# ---------------------------------------------------------------------------
# plan cache: determinism + observable hit (ISSUE 6 acceptance)
# ---------------------------------------------------------------------------


def test_cache_determinism_and_hit(tmp_path):
    g2 = _pass2(df.dual_sublayer_graph())
    cache = PlanCache(root=str(tmp_path))
    p1 = PerfsimPlanner(fabric=FABRIC, cache=cache)
    out1 = p1.pair(g2)
    assert cache.stats == {"hits": 0, "misses": 1}
    p2 = PerfsimPlanner(fabric=FABRIC, cache=cache)
    out2 = p2.pair(g2)
    assert cache.stats == {"hits": 1, "misses": 1}
    assert p1.plan == p2.plan
    assert [n.name for n in out1.nodes] == [n.name for n in out2.nodes]


def test_cache_persists_across_instances(tmp_path):
    """A fresh PlanCache over the same root reloads the persisted JSON —
    the cross-process hit the reports/plans/ artifact exists for."""
    g2 = _pass2(df.dual_sublayer_graph())
    PerfsimPlanner(fabric=FABRIC, cache=PlanCache(root=str(tmp_path))).pair(g2)
    cache2 = PlanCache(root=str(tmp_path))
    p = PerfsimPlanner(fabric=FABRIC, cache=cache2)
    p.pair(g2)
    assert cache2.stats == {"hits": 1, "misses": 0}


def test_cache_key_sensitive_to_shapes_and_backend(tmp_path):
    g2 = _pass2(df.dual_sublayer_graph())
    cache = PlanCache(root=str(tmp_path))
    PerfsimPlanner(fabric=FABRIC, cache=cache).pair(g2)
    # different backend → different key → miss
    PerfsimPlanner(fabric=FABRIC, backend="barrier", cache=cache).pair(g2)
    # different shapes → different key → miss
    PerfsimPlanner(fabric=FABRIC, cache=cache,
                   value_shapes={"xa": (4, 64, 64), "xb": (4, 64, 64)}
                   ).pair(g2)
    assert cache.stats == {"hits": 0, "misses": 3}


def test_search_deterministic():
    g2 = _pass2(df.dual_sublayer_graph())
    a = search_pairing(g2, fabric=FABRIC)
    b = search_pairing(g2, fabric=FABRIC)
    assert a == b


# ---------------------------------------------------------------------------
# calibration (fits the committed bench JSON within the pinned tolerance)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not os.path.exists(BENCH_JSON),
                    reason="committed bench artifact missing")
def test_calibration_fits_committed_bench():
    res = calibrate(BENCH_JSON)
    assert isinstance(res, CalibrationResult)
    assert res.ratios, "no barrier cells found in the bench JSON"
    assert res.within_tolerance, (res.ratios, res.max_abs_log_ratio,
                                  RATIO_TOLERANCE)
    assert res.fabric.bw > 0 and res.fabric.alpha > 0
    assert 0 < res.fabric.mxu_eff <= 1.0


def test_fabric_from_hw():
    from repro.hw import V5E

    f = fabric_from_hw(V5E, 8)
    assert f.n == 8
    assert f.bw == V5E.ici_bw
    assert f.alpha == V5E.hop_latency
    assert f.peak == V5E.peak_flops
    assert not f.two_tier        # flat by default — PR-8-era call sites hold


# ---------------------------------------------------------------------------
# two-tier fabric (hierarchical 2D mesh — docs/topology.md)
# ---------------------------------------------------------------------------


def test_fabric_from_hw_two_tier():
    from repro.hw import V5E

    f = fabric_from_hw(V5E, 8, n_outer=4)
    assert f.two_tier
    assert f.n == 8 and f.n_outer == 4 and f.n_inner == 2
    assert f.bw2 == V5E.dcn_bw
    assert f.alpha2 == V5E.dcn_latency
    assert f.bw2 < f.bw and f.alpha2 > f.alpha   # DCN slower than ICI


def _two_tier(**kw):
    import dataclasses

    base = Fabric(n=8)
    return dataclasses.replace(
        base, n_outer=kw.pop("n_outer", 4),
        bw2=kw.pop("bw2", base.bw / 20), alpha2=kw.pop("alpha2", 2e-4),
        **kw)


def test_two_tier_simulation_prices_slow_tier():
    """The per-axis lowering decomposes each collective into inner + outer
    legs; a slow outer tier must make the same graph strictly slower than
    the flat ring, for both backends, and the cais advantage must hold
    per tier."""
    g = df.optimize(df.sublayer_graph())
    f2 = _two_tier()
    for backend in ("barrier", "cais"):
        m_flat = simulate(g, FABRIC, policy_for_backend(backend))
        m_2t = simulate(g, f2, policy_for_backend(backend))
        assert m_2t > m_flat
    # chunked rings lose to barriers on a latency-dominated outer tier
    # unless the outer leg is chunked minimally — with a per-axis choice
    # the cais schedule regains the win (the planner's job to find)
    m_barrier = simulate(g, f2, policy_for_backend("barrier"))
    m_cais = min(simulate(g, f2, policy_for_backend("cais"), num_chunks=c)
                 for c in (None, 2, (2, 1), (4, 1)))
    assert m_cais < m_barrier


def test_two_tier_per_axis_chunking():
    """(inner, outer) chunk tuples lower per-tier and price differently:
    outer chunks multiply the expensive alpha2, inner chunks the cheap
    alpha — so chunking the slow tier harder must cost more."""
    g = df.optimize(df.sublayer_graph())
    f2 = _two_tier()
    policy = policy_for_backend("cais")
    shapes = dict(value_shapes={"x": (8, 512, 1024)},
                  weight_shapes={"w1": (1024, 1024), "w2": (1024, 1024),
                                 "scale": (1024,)})
    few_outer = simulate(g, f2, policy, num_chunks=(4, 2), **shapes)
    many_outer = simulate(g, f2, policy, num_chunks=(4, 16), **shapes)
    assert few_outer < many_outer


def test_planner_diverges_between_tiers():
    """ISSUE-9 acceptance: on an asymmetric fabric the perfsim planner must
    choose a different plan for the two-tier topology than for the flat
    ring of the same total size — the whole reason Fabric carries a second
    tier at all."""
    import dataclasses

    g2 = _pass2(df.dual_sublayer_graph())
    shapes = dict(value_shapes={"xa": (8, 512, 4096), "xb": (8, 512, 4096)},
                  weight_shapes={"wa": (4096, 4096), "wb": (4096, 4096)})
    asym = dataclasses.replace(Fabric(n=8), alpha=1e-7, n_outer=4,
                               bw2=Fabric(n=8).bw / 20, alpha2=2e-4)
    p_flat = search_pairing(g2, fabric=FABRIC, **shapes)
    p_2t = search_pairing(g2, fabric=asym, **shapes)
    assert p_flat.num_chunks != p_2t.num_chunks, (p_flat, p_2t)


def test_two_tier_plan_roundtrips_through_cache_dict():
    """A per-axis (inner, outer) chunk tuple must survive the plan-cache
    JSON round trip (lists come back as tuples)."""
    import json

    from repro.plan.search import Plan

    p = Plan(pairing=(("a", "b"),), num_chunks=(16, 2), num_microbatches=1,
             makespan=1.0, greedy_makespan=2.0, backend="cais")
    assert Plan.from_dict(json.loads(json.dumps(p.to_dict()))) == p
