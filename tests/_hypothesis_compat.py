"""Use hypothesis when installed; otherwise a deterministic fallback.

The container image does not always ship `hypothesis` (see
requirements-dev.txt), and a missing property-testing dependency must not
break tier-1 *collection*. When the real library is absent, ``given`` runs
the test over a small deterministic grid of boundary/midpoint samples per
strategy (capped product) and ``settings`` is a no-op — weaker than real
property testing, but the invariants still get exercised.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback mini-strategies
    import itertools

    HAVE_HYPOTHESIS = False
    _MAX_CASES = 24

    class _Samples:
        def __init__(self, values):
            self.values = list(values)

    class _St:
        @staticmethod
        def floats(lo, hi):
            return _Samples([lo, hi, (lo + hi) / 2, lo + (hi - lo) * 0.1])

        @staticmethod
        def integers(lo, hi):
            return _Samples(sorted({lo, min(lo + 1, hi), (lo + hi) // 2, hi}))

        @staticmethod
        def sampled_from(values):
            return _Samples(values)

        @staticmethod
        def booleans():
            return _Samples([False, True])

    st = _St()

    def given(**strategies):
        names = list(strategies)

        def deco(fn):
            # plain zero-arg wrapper: pytest must not see the strategy
            # kwargs in the signature (it would treat them as fixtures)
            def wrapper():
                cases = list(itertools.product(
                    *(strategies[n].values for n in names)))
                # stride-sample so every strategy's boundary values appear
                # (a prefix cut would only ever vary the last strategy)
                step = max(1, -(-len(cases) // _MAX_CASES))
                for combo in cases[::step]:
                    fn(**dict(zip(names, combo)))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(**kwargs):
        def deco(fn):
            return fn

        return deco
