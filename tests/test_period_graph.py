"""Period-level dataflow graphs (ISSUE 3 tentpole): ≥2 blocks of a
``layer_pattern`` period concatenated into ONE graph, so the optimizer sees
the block→block seams — plus the merge_graphs weight-prefixing semantics and
the deterministic pass-3 pairing policy that ride along.

ISSUE 5 adds the in-model microbatch split: ``sp_period(num_microbatches=n)``
merges n independent per-microbatch chains into that one graph
(shared weights), which is what finally lets pass 3 emit ``overlap_asym``
inside the model path — a straight-line period is fully serialized after
pass-2 fusion. Covered here: the split graph carries ≥1 ``overlap_asym``,
``optimize()`` stays idempotent on it, ``num_microbatches=1`` is
bit-identical to the unsplit path, and ``pair_asymmetric`` refuses
same-chain pairs (the chain-id guard)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import tp


def _toy_core(q, k, v):
    # stand-in attention core: local math with the same (B, S, d) layout
    return q * jax.nn.sigmoid(k) + v


def _period_weights(key, n_blocks=2, d=16, f=24):
    w = {}
    for i in range(n_blocks):
        p = f"b{i}."
        ks = jax.random.split(jax.random.fold_in(key, i), 9)
        w[p + "scale1"] = jax.random.normal(ks[0], (d,)) * 0.1 + 1.0
        for j, kk in enumerate(("wq", "wk", "wv", "wo")):
            w[p + kk] = jax.random.normal(ks[1 + j], (d, d)) * 0.1
        w[p + "scale2"] = jax.random.normal(ks[5], (d,)) * 0.1 + 1.0
        w[p + "w_up"] = jax.random.normal(ks[6], (d, f)) * 0.1
        w[p + "w_gate"] = jax.random.normal(ks[7], (d, f)) * 0.1
        w[p + "w_down"] = jax.random.normal(ks[8], (f, d)) * 0.1
    return w


def _cross_block_nodes(g):
    """Fused/paired nodes whose weights span more than one block prefix."""
    def prefixes(n):
        return {w.split(".")[0] for w in n.weights if "." in w}
    return [n for n in g.nodes
            if n.op in ("fused_rs_ln_ag", "fused_rs_ln_ag_multi",
                        "overlap_asym") and len(prefixes(n)) > 1]


def test_period_graph_fuses_cross_block_seam():
    """Acceptance: the optimized 2-block dense period graph must contain a
    cross-block pass-3 overlap_asym OR cross-block pass-2 fusion node —
    here pass 2 fuses block 0's FFN-out RS → residual → block 1's LN1 →
    QKV shared gather into one fused_rs_ln_ag_multi spanning both blocks."""
    g = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    opt = df.optimize(g)
    cross = _cross_block_nodes(opt)
    assert cross, [(n.op, n.name) for n in opt.nodes]
    # the seam carries block 0's down-proj and block 1's LN1 + QKV weights
    seam = cross[0]
    assert "b0.w_down" in seam.weights and "b1.wq" in seam.weights
    # no raw collective survives optimization inside the period
    assert not ({"allgather", "reduce_scatter"}
                & {n.op for n in opt.nodes})


def test_period_graph_optimize_idempotent():
    g = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    opt = df.optimize(g)
    opt2 = df.optimize(opt)
    assert [(n.name, n.op) for n in opt.nodes] == \
        [(n.name, n.op) for n in opt2.nodes]


def test_period_graph_reference_semantics():
    """optimize() must preserve the math of the period graph (single-device
    reference), gated and non-gated."""
    for has_gate, act in ((True, "silu"), (False, "gelu")):
        g = tp.dense_period_graph([_toy_core, _toy_core], has_gate, act)
        w = _period_weights(jax.random.key(0))
        if not has_gate:
            w = {k: v for k, v in w.items() if not k.endswith("w_gate")}
        x = jax.random.normal(jax.random.key(1), (2, 8, 16))
        a = df.execute(g, {"x": x}, w)[0]
        b = df.execute(df.optimize(g), {"x": x}, w)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_seam_fuses_rs_ln_before_route():
    """Pass 2's MoE variant: attention-out gemm_rs → residual → LN2 →
    route fuses into fused_rs_ln (the trailing collective is the expert
    a2a, not a gather), re-exposing the normed value for route/unroute."""
    def route(xn, router):
        return jnp.stack([xn, 2.0 * xn]), jnp.float32(0.5), \
            jnp.zeros((1,), jnp.float32)

    def expert(chunk, wu, wd):
        return jax.nn.gelu(chunk @ wu) @ wd

    def unroute(eout, combine, xn):
        return combine * (eout[0] + eout[1])

    g = tp.moe_block_graph(_toy_core, route, expert, unroute,
                           ("w_up", "w_down"), False)
    opt = df.optimize(g)
    ops = [n.op for n in opt.nodes]
    assert "fused_rs_ln" in ops
    assert {"route", "a2a_ffn", "unroute"} <= set(ops)
    # idempotent here too
    assert [(n.name, n.op) for n in df.optimize(opt).nodes] == \
        [(n.name, n.op) for n in opt.nodes]
    # reference semantics
    d, f = 16, 24
    ks = jax.random.split(jax.random.key(2), 9)
    w = {"scale1": jax.random.normal(ks[0], (d,)) * 0.1 + 1.0,
         "scale2": jax.random.normal(ks[5], (d,)) * 0.1 + 1.0,
         "router": jax.random.normal(ks[6], (d, 4)) * 0.1,
         "w_up": jax.random.normal(ks[7], (d, f)) * 0.1,
         "w_down": jax.random.normal(ks[8], (f, d)) * 0.1}
    for j, kk in enumerate(("wq", "wk", "wv", "wo")):
        w[kk] = jax.random.normal(ks[1 + j], (d, d)) * 0.1
    x = jax.random.normal(jax.random.key(3), (2, 8, d))
    a = df.execute(g, {"x": x}, w)
    b = df.execute(opt, {"x": x}, w)
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-5)


def test_merge_graphs_prefixes_weights_by_default():
    """Merging graphs of DIFFERENT blocks must not alias wq/w_up/… — weight
    keys are namespaced like values unless share_weights=True."""
    g = df.merge_graphs([tp.dense_block_graph(_toy_core, True, "silu"),
                         tp.dense_block_graph(_toy_core, True, "silu")])
    wkeys = {w for n in g.nodes for w in n.weights}
    assert all(k.startswith(("mb0.", "mb1.")) for k in wkeys)
    # distinct per-block params flow to the right copy
    w = _period_weights(jax.random.key(4))
    w = {("mb" + k[1:]): v for k, v in w.items()}     # b0./b1. → mb0./mb1.
    x = jax.random.normal(jax.random.key(5), (2, 8, 16))
    outs = df.execute(df.optimize(g), {"mb0.x": x, "mb1.x": x}, w)
    single0 = tp.dense_block_graph(_toy_core, True, "silu")
    ref0 = df.execute(single0, {"x": x},
                      {k[4:]: v for k, v in w.items()
                       if k.startswith("mb0.")})[0]
    ref1 = df.execute(single0, {"x": x},
                      {k[4:]: v for k, v in w.items()
                       if k.startswith("mb1.")})[0]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref0),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(ref1),
                               atol=1e-5)


def test_merge_graphs_share_weights_opt_out():
    g = df.merge_graphs([df.sublayer_graph(), df.sublayer_graph()],
                        share_weights=True)
    wkeys = {w for n in g.nodes for w in n.weights}
    assert wkeys == {"w1", "scale", "w2"}


def test_merge_graphs_duplicate_prefix_raises():
    with pytest.raises(df.GraphError, match="dup."):
        df.merge_graphs([df.sublayer_graph(), df.sublayer_graph()],
                        prefixes=["dup.", "dup."])
    with pytest.raises(df.GraphError, match="prefixes"):
        df.merge_graphs([df.sublayer_graph()], prefixes=["a.", "b."])


def test_pair_asymmetric_deterministic_nearest_first():
    """Two merged microbatch period chains: pass 3 must pick the ADJACENT
    independent seam (nearest topological distance), identically on every
    run — not whatever pair node order surfaces first."""
    mk = lambda: tp.dense_block_graph(_toy_core, True, "silu")
    g = df.merge_graphs([mk(), mk()], share_weights=True)
    opt1 = df.optimize(g)
    opt2 = df.optimize(df.merge_graphs([mk(), mk()], share_weights=True))
    names1 = [(n.name, n.op) for n in opt1.nodes]
    assert names1 == [(n.name, n.op) for n in opt2.nodes]
    pairs = [n for n in opt1.nodes if n.op == "overlap_asym"]
    # one cross-microbatch pair forms (the fusion itself then serializes the
    # two chains, so the remaining RS/AG pair is correctly left alone)
    assert len(pairs) == 1
    # nearest-first: mb0's FFN-out RS pairs with mb1's attention gather —
    # the adjacent seam, not an arbitrary first match
    assert pairs[0].name == "mb0.rs2+mb1.q+mb1.k+mb1.v", pairs[0].name


def test_pair_asymmetric_same_chain_guard():
    """Chain-id guard (ISSUE 5 satellite): a gemm_rs/ag_gemm pair fed by the
    SAME microbatch's data — dependency-free only because of a fork off one
    input — must NOT pair even though topo distance ranks it nearest;
    pairing would lockstep-serialize the chain against itself. The
    two-input twin (independent microbatches) still pairs."""
    nodes = [
        df.Node("x", "input"),
        df.Node("ga", "gemm_row", ("x",), ("wa",)),
        df.Node("rsa", "reduce_scatter", ("ga",)),
        df.Node("agb", "allgather", ("x",)),
        df.Node("gb", "gemm_col", ("agb",), ("wb",)),
    ]
    opt = df.optimize(df.Graph(nodes, outputs=("rsa", "gb")))
    ops = {n.op for n in opt.nodes}
    assert "overlap_asym" not in ops, [(n.name, n.op) for n in opt.nodes]
    assert {"gemm_rs", "ag_gemm"} <= ops
    # the dual-INPUT version is two chains: pass 3 pairs it as before
    dual = df.optimize(df.dual_sublayer_graph())
    assert [n.op for n in dual.nodes if n.op != "input"] == ["overlap_asym"]


def _split_period_graph(num_microbatches, n_blocks=2):
    """The graph sp_period actually builds for a dense period at the given
    microbatch split, via the same builder seam it uses."""
    from repro import sharding
    from repro.core.primitives import CAISConfig

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais",
                       cais=CAISConfig(num_chunks=1))
    from repro.configs import get_arch
    import repro.models.transformer as tr
    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=n_blocks, d_model=32, num_heads=4, num_kv_heads=4,
        head_dim=8, d_ff=48)
    kinds = ("attn",) * n_blocks
    ps = [tr.init_block(jax.random.key(60 + i), k, cfg, jnp.float32)
          for i, k in enumerate(kinds)]
    base, _, _, _ = tp._period_graph(tpc, ps, cfg, kinds)
    return tp.microbatch_period_graph(base, num_microbatches)


def test_microbatch_split_period_unlocks_overlap_asym():
    """Acceptance (ISSUE 5): the straight-line dense period is fully
    serialized after pass-2 fusion (no overlap_asym), while the
    microbatch-split period graph — the one sp_period builds for
    num_microbatches=2 — carries ≥1 pass-3 overlap_asym node pairing
    collectives from DIFFERENT chains."""
    unsplit = df.optimize(_split_period_graph(1))
    assert not any(n.op == "overlap_asym" for n in unsplit.nodes)
    opt = df.optimize(_split_period_graph(2))
    pairs = [n for n in opt.nodes if n.op == "overlap_asym"]
    assert pairs, [(n.name, n.op) for n in opt.nodes]
    # the pair really crosses chains: its name carries both mb prefixes
    assert any("mb0." in n.name and "mb1." in n.name for n in pairs), \
        [n.name for n in pairs]


def test_microbatch_split_period_optimize_idempotent():
    opt = df.optimize(_split_period_graph(2))
    opt2 = df.optimize(opt)
    assert [(n.name, n.op) for n in opt.nodes] == \
        [(n.name, n.op) for n in opt2.nodes]


def test_sp_period_microbatch_parity_and_identity():
    """num_microbatches=1 must be BIT-identical to the default (unsplit)
    path; num_microbatches=2 and "auto" must pin ≤1e-6 against it (exact on
    a tp=1 mesh: the split is pure batch reshaping)."""
    import repro.models.transformer as tr
    from repro import sharding
    from repro.configs import get_arch
    from repro.core.primitives import CAISConfig

    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=48)
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais",
                       cais=CAISConfig(num_chunks=1))
    kinds = ("attn", "attn")
    ps = [tr.init_block(jax.random.key(7 + i), k, cfg, jnp.float32)
          for i, k in enumerate(kinds)]
    x = jax.random.normal(jax.random.key(8), (4, 16, 32), jnp.float32)
    got1, _ = tp.sp_period(tpc, x, ps, cfg, kinds)
    got1b, _ = tp.sp_period(tpc, x, ps, cfg, kinds, num_microbatches=1)
    assert (np.asarray(got1) == np.asarray(got1b)).all()
    got2, _ = tp.sp_period(tpc, x, ps, cfg, kinds, num_microbatches=2)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got1), atol=1e-6)
    gota, _ = tp.sp_period(tpc, x, ps, cfg, kinds, num_microbatches="auto")
    np.testing.assert_allclose(np.asarray(gota), np.asarray(got1), atol=1e-6)
    # the TPContext knob is the default the argument overrides
    tpc2 = tp.TPContext(mesh=mesh, backend="cais",
                        cais=CAISConfig(num_chunks=1), num_microbatches=2)
    gotk, _ = tp.sp_period(tpc2, x, ps, cfg, kinds)
    np.testing.assert_allclose(np.asarray(gotk), np.asarray(got2), atol=0)


def test_resolve_microbatches_clamps_to_batch_divisors():
    from repro import sharding
    from repro.core.primitives import CAISConfig

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais", cais=CAISConfig())
    x = jnp.zeros((6, 16, 32))
    assert tp.resolve_microbatches(tpc, x, 4) == 3   # largest divisor ≤ 4
    assert tp.resolve_microbatches(tpc, x, 2) == 2
    assert tp.resolve_microbatches(tpc, jnp.zeros((1, 16, 32)), 8) == 1
    assert tp.resolve_microbatches(tpc, x) == 1      # knob default: unsplit
    # "auto" never splits an MoE period (its aux statistic is not linear
    # over sub-batches) — only an explicit integer opts in
    assert tp.resolve_microbatches(tpc, x, "auto", moe=True) == 1
    assert tp.resolve_microbatches(tpc, x, 2, moe=True) == 2


def test_plan_microbatches_heuristic():
    """coordination.plan_microbatches: split only while each chain's α-β
    plan keeps ≥2 latency-healthy chunks, never beyond batch divisibility."""
    from repro.core import coordination as co

    assert co.plan_microbatches(4, 256e6, 4) > 1     # big payload: split
    assert co.plan_microbatches(4, 4096, 4) == 1     # latency floor: don't
    assert co.plan_microbatches(1, 256e6, 4) == 1    # nothing to split
    assert co.plan_microbatches(4, 256e6, 1) == 1    # no ring, no point
    assert co.plan_microbatches(3, 256e6, 4) == 1    # 2 does not divide 3
    assert co.plan_microbatches(8, 1e9, 8,
                                max_microbatches=8) in (2, 4, 8)


def test_remat_covers_rem_tail():
    """num_layers % len(layer_pattern) != 0 leaves tail blocks outside the
    scanned periods; remat must wrap them too (ISSUE 3 satellite) — loss and
    grads with remat on/off must match."""
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.runtime import Runtime

    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=3, layer_pattern=("attn", "attn"), d_model=32,
        num_heads=4, num_kv_heads=4, head_dim=8, d_ff=48)
    assert cfg.num_layers % len(cfg.layer_pattern) != 0
    tokens = jax.random.randint(jax.random.key(0), (2, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    losses, grads = {}, {}
    for remat in (False, True):
        rt = Runtime(compute_dtype="float32", remat=remat, loss_chunk=16)
        model = build_model(cfg, rt)
        params = model.init(jax.random.key(1))
        losses[remat], grads[remat] = jax.value_and_grad(model.loss)(
            params, batch)
    np.testing.assert_allclose(float(losses[True]), float(losses[False]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(grads[True]),
                    jax.tree.leaves(grads[False])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sp_period_matches_per_block_single_device():
    """sp_period (one graph per period) vs the per-block sp_block
    composition on a tp=1 mesh — dense 2-block period."""
    import repro.models.transformer as tr
    from repro import sharding
    from repro.configs import get_arch
    from repro.core.primitives import CAISConfig

    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=48)
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais",
                       cais=CAISConfig(num_chunks=1))
    kinds = ("attn", "attn")
    ps = [tr.init_block(jax.random.key(7 + i), k, cfg, jnp.float32)
          for i, k in enumerate(kinds)]
    x = jax.random.normal(jax.random.key(8), (2, 16, 32), jnp.float32)
    got, aux = tp.sp_period(tpc, x, ps, cfg, kinds)
    ref = x
    for p_, k_ in zip(ps, kinds):
        ref, _ = tp.sp_block(tpc, ref, p_, cfg, k_)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
