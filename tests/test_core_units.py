"""Unit + property tests for the CAIS core: coordination scheduler, dataflow
optimizer (single-device reference semantics), and the calibrated perfsim."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import coordination as coord
from repro.core import dataflow as df
from repro.core import perfsim as ps
from repro.hw import V5E

# ---------------------------------------------------------------------------
# coordination
# ---------------------------------------------------------------------------


@given(payload=st.floats(1e4, 1e10), ring=st.integers(2, 64),
       chunks=st.integers(1, 128))
@settings(max_examples=200, deadline=None)
def test_schedule_metrics_invariants(payload, ring, chunks):
    m = coord.schedule_metrics(payload, ring, chunks)
    assert m.staging_bytes >= 0
    assert m.step_time > 0
    assert 0 <= m.latency_fraction <= 1
    # staging bytes shrink monotonically with more chunks
    m2 = coord.schedule_metrics(payload, ring, chunks * 2)
    assert m2.staging_bytes <= m.staging_bytes


@given(payload=st.floats(1e6, 1e10), ring=st.integers(2, 64))
@settings(max_examples=100, deadline=None)
def test_plan_respects_budget(payload, ring):
    budget = 4 * 1024**2
    p = coord.plan(payload, ring, staging_budget=budget)
    assert p.staging_bytes <= budget
    assert p.num_chunks >= 1


def test_plan_latency_guard():
    # tiny payloads must not be shredded into latency-dominated chunks
    p = coord.plan(64 * 1024, ring=16)
    assert p.num_chunks <= 4


def test_plan_max_chunks_cap_and_over_cap_signal():
    # a payload whose latency bound allows >64 chunks but whose staging
    # budget does not demand them: the cap clamps, no over_cap flag
    p = coord.plan(1e9, ring=8, staging_budget=1024**3)
    assert p.num_chunks == 64
    assert not p.over_cap
    # when the staging budget itself forces >max_chunks the budget wins
    # (hard resource) and the plan says so instead of silently exceeding
    p2 = coord.plan(1e10, ring=2, staging_budget=4 * 1024**2)
    assert p2.num_chunks > 64
    assert p2.over_cap
    # a custom cap behaves the same way
    p3 = coord.plan(1e9, ring=8, staging_budget=1024**3, max_chunks=16)
    assert p3.num_chunks == 16 and not p3.over_cap


def test_plan_compute_time_prefers_coarser_chunks():
    """With compute_time the planner stops adding chunks once wire time no
    longer hides under compute: scarce compute → coarser chunking, while
    abundant compute keeps the latency-bound chunking."""
    payload, ring = 1e9, 8
    free = coord.plan(payload, ring, staging_budget=1024**3)
    tight = coord.plan(payload, ring, staging_budget=1024**3,
                       compute_time=1e-4)
    loose = coord.plan(payload, ring, staging_budget=1024**3,
                       compute_time=10.0)
    assert tight.num_chunks <= free.num_chunks
    assert tight.num_chunks < loose.num_chunks
    assert loose.num_chunks == free.num_chunks
    # the staging floor still wins over the compute fit
    floor = coord.plan(1e10, ring=2, staging_budget=4 * 1024**2,
                       compute_time=1e-6)
    assert floor.staging_bytes <= 4 * 1024**2


def test_plan_microbatches_injectable_hw():
    """Tiny payloads don't split under V5E's hop latency (per-chain chunks
    would hit the latency floor), but on a scaled-down fabric — the same
    payload:latency ratio a real payload sees — the split engages (>1)."""
    import dataclasses as _dc

    batch, payload, ring = 8, 256 * 1024, 8
    assert coord.plan_microbatches(batch, payload, ring) == 1
    tiny_hw = _dc.replace(V5E, hop_latency=V5E.hop_latency / 1e4)
    assert coord.plan_microbatches(batch, payload, ring, hw=tiny_hw) > 1


# ---------------------------------------------------------------------------
# dataflow (reference semantics, single device)
# ---------------------------------------------------------------------------


def _graph_weights(key, d=16, f=24):
    ks = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(ks[0], (d, f)) * 0.1,
        "scale": jax.random.normal(ks[1], (f,)) * 0.1,
        "w2": jax.random.normal(ks[2], (f, d)) * 0.1,
    }


def test_optimize_fuses_sublayer():
    g = df.optimize(df.sublayer_graph())
    ops = [n.op for n in g.nodes if n.op != "input"]
    assert ops == ["fused_rs_ln_ag"]


def test_optimize_pairs_asymmetric():
    g = df.optimize(df.dual_sublayer_graph())
    ops = [n.op for n in g.nodes if n.op != "input"]
    assert ops == ["overlap_asym"]


def test_optimize_preserves_semantics_reference():
    g = df.sublayer_graph()
    opt = df.optimize(g)
    w = _graph_weights(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    a = df.execute(g, {"x": x}, w)[0]
    b = df.execute(opt, {"x": x}, w)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fusion_legal_when_rs_escapes():
    """rs as a graph output: still fusable — fused_rs_ln_ag re-exposes z."""
    nodes = [
        df.Node("x", "input"),
        df.Node("g1", "gemm_row", ("x",), ("w1",)),
        df.Node("rs", "reduce_scatter", ("g1",)),
        df.Node("ln", "layernorm", ("rs",), ("scale",)),
        df.Node("ag", "allgather", ("ln",)),
        df.Node("g2", "gemm_col", ("ag",), ("w2",)),
    ]
    g = df.Graph(list(nodes), outputs=("g2", "rs"))
    opt = df.optimize(g)
    assert "fused_rs_ln_ag" in [n.op for n in opt.nodes]
    w = _graph_weights(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    a = df.execute(g, {"x": x}, w)
    b = df.execute(opt, {"x": x}, w)
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-5)


def test_no_fuse_when_pre_add_value_escapes():
    """gemm_rs → residual → ln → ag where the PRE-add rs value is itself a
    graph output: the fused op re-exposes only the post-add z, so pass 2
    must skip the chain (not drop the output and crash)."""
    nodes = [
        df.Node("x", "input"),
        df.Node("res", "input"),
        df.Node("g1", "gemm_row", ("x",), ("w1",)),
        df.Node("rs", "reduce_scatter", ("g1",)),
        df.Node("r1", "residual", ("rs", "res")),
        df.Node("ln", "layernorm", ("r1",), ("scale",)),
        df.Node("ag", "allgather", ("ln",)),
        df.Node("g2", "gemm_col", ("ag",), ("w2",)),
    ]
    g = df.Graph(list(nodes), outputs=("g2", "rs"))
    opt = df.optimize(g)                       # must not raise GraphError
    ops = {n.op for n in opt.nodes}
    assert "fused_rs_ln_ag" not in ops
    assert {"gemm_rs", "ag_gemm"} <= ops
    w = _graph_weights(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    res = jax.random.normal(jax.random.key(2), (2, 8, 24))
    a = df.execute(g, {"x": x, "res": res}, w)
    b = df.execute(opt, {"x": x, "res": res}, w)
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-5)


def test_no_fuse_when_intermediate_escapes():
    """ln output escaping the chain blocks the deep fusion (it is not
    re-exposed by the fused op), but pass-1 alignment still applies."""
    nodes = [
        df.Node("x", "input"),
        df.Node("g1", "gemm_row", ("x",), ("w1",)),
        df.Node("rs", "reduce_scatter", ("g1",)),
        df.Node("ln", "layernorm", ("rs",), ("scale",)),
        df.Node("ag", "allgather", ("ln",)),
        df.Node("g2", "gemm_col", ("ag",), ("w2",)),
    ]
    g = df.Graph(list(nodes), outputs=("g2", "ln"))
    opt = df.optimize(g)
    ops = {n.op for n in opt.nodes}
    assert "fused_rs_ln_ag" not in ops
    assert {"gemm_rs", "ag_gemm"} <= ops


# ---------------------------------------------------------------------------
# whole-block dataflow graphs (ISSUE 2 tentpole): pass 2 and pass 3 must
# demonstrably rewrite nodes on a dense-config block
# ---------------------------------------------------------------------------


def _toy_core(q, k, v):
    # stand-in attention core: local math with the same (B, S, d) layout
    return q * jax.nn.sigmoid(k) + v


def _block_weights(key, d=16, f=24):
    ks = jax.random.split(key, 9)
    return {
        "scale1": jax.random.normal(ks[0], (d,)) * 0.1 + 1.0,
        "wq": jax.random.normal(ks[1], (d, d)) * 0.1,
        "wk": jax.random.normal(ks[2], (d, d)) * 0.1,
        "wv": jax.random.normal(ks[3], (d, d)) * 0.1,
        "wo": jax.random.normal(ks[4], (d, d)) * 0.1,
        "scale2": jax.random.normal(ks[5], (d,)) * 0.1 + 1.0,
        "w_up": jax.random.normal(ks[6], (d, f)) * 0.1,
        "w_gate": jax.random.normal(ks[7], (d, f)) * 0.1,
        "w_down": jax.random.normal(ks[8], (f, d)) * 0.1,
    }


def test_block_graph_pass2_fuses_cross_sublayer_seam():
    """On a gated dense block (every dense model in configs/ is gated silu)
    pass 2 must fuse attention-out RS → residual → LN2 → FFN-in shared
    gather into ONE fused_rs_ln_ag_multi pipeline."""
    from repro.core import tp

    g = df.optimize(tp.dense_block_graph(_toy_core, True, "silu"))
    ops = [n.op for n in g.nodes]
    assert "fused_rs_ln_ag_multi" in ops          # pass 2 rewrote the seam
    assert "ag_gemm_multi" in ops                 # QKV shared gather (pass 1b)
    # every raw collective was consumed by a fusion pass
    assert not ({"allgather", "reduce_scatter"} & set(ops))
    # the non-gated variant fuses to the single-weight pipeline
    g2 = df.optimize(tp.dense_block_graph(_toy_core, False, "gelu"))
    assert "fused_rs_ln_ag" in [n.op for n in g2.nodes]


def test_block_graph_pass3_pairs_across_microbatches():
    """Two independent microbatches of the same dense block merged into one
    graph: pass 3 must co-schedule one microbatch's FFN-out gemm_rs against
    the other's attention-in shared gather (overlap_asym)."""
    from repro.core import tp

    g = df.merge_graphs([tp.dense_block_graph(_toy_core, True, "silu"),
                         tp.dense_block_graph(_toy_core, True, "silu")],
                        share_weights=True)
    opt = df.optimize(g)
    assert any(n.op == "overlap_asym" for n in opt.nodes)


def test_block_graph_reference_semantics():
    """optimize() must preserve the math of the whole-block graph (single
    device reference), for both the single and dual-microbatch forms."""
    from repro.core import tp

    w = _block_weights(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    g = tp.dense_block_graph(_toy_core, True, "silu")
    a = df.execute(g, {"x": x}, w)[0]
    b = df.execute(df.optimize(g), {"x": x}, w)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    merged = df.merge_graphs([tp.dense_block_graph(_toy_core, True, "silu"),
                              tp.dense_block_graph(_toy_core, True, "silu")],
                             share_weights=True)
    vals = {"mb0.x": x, "mb1.x": x[::-1]}
    outs_a = df.execute(merged, vals, w)
    outs_b = df.execute(df.optimize(merged), vals, w)
    for u, v in zip(outs_a, outs_b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=1e-5)


def test_sp_block_matches_split_path_single_device():
    """sp_block (one graph per block) vs the PR-1 per-sub-layer composition
    on a tp=1 mesh — dense and MoE."""
    import dataclasses

    import repro.models.transformer as tr
    from repro import sharding
    from repro.configs import get_arch
    from repro.core import tp

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    d = 32
    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=64)
    params = tr.init_block(jax.random.key(0), "attn", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)
    tpc = tp.TPContext(mesh=mesh, backend="cais")
    got, aux = tp.sp_block(tpc, x, params, cfg, "attn")
    m, f = params["mixer"], params["ffn"]
    r1 = x + tp.sp_attention(tpc, x, params["norm1"]["scale"], m["wq"],
                             m["wk"], m["wv"], m["wo"], cfg)
    ref = r1 + tp.sp_ffn(tpc, r1, params["norm2"]["scale"], f["w_up"],
                         f.get("w_gate"), f["w_down"], cfg.act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    assert float(aux) == 0.0

    cfg_moe = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=32, window=16)
    cfg_moe = cfg_moe.scaled(moe=dataclasses.replace(
        cfg_moe.moe, capacity_factor=8.0))
    params = tr.init_block(jax.random.key(2), "attn", cfg_moe, jnp.float32)
    got, aux = tp.sp_block(tpc, x, params, cfg_moe, "attn")
    m = params["mixer"]
    r1 = x + tp.sp_attention(tpc, x, params["norm1"]["scale"], m["wq"],
                             m["wk"], m["wv"], m["wo"], cfg_moe)
    out, aux_ref = tp.sp_moe_ffn(tpc, r1, params["norm2"]["scale"],
                                 params["ffn"], cfg_moe)
    np.testing.assert_allclose(np.asarray(got), np.asarray(r1 + out),
                               atol=1e-6)
    np.testing.assert_allclose(float(aux), float(aux_ref), atol=1e-6)


# ---------------------------------------------------------------------------
# perfsim — trend reproduction against the paper's reported numbers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fabric():
    return ps.calibrated_fabric()


@pytest.fixture(scope="module")
def geomeans(fabric):
    tbl = ps.speedup_table(f=fabric)
    return {b: ps.geomean(tbl[m][b] for m in tbl)
            for b in next(iter(tbl.values()))}


def test_speedups_within_band(geomeans):
    """Each simulated geomean within ±25% of the paper's (Fig. 11)."""
    for b, v in geomeans.items():
        paper = ps.PAPER_GEOMEANS_TRAIN.get(b)
        if paper is None:
            continue
        assert 0.75 * paper <= v <= 1.25 * paper, (b, v, paper)


def test_speedup_orderings(geomeans):
    """Key qualitative claims of Fig. 11."""
    g = geomeans
    assert all(v > 1.0 for b, v in g.items() if b != "CAIS"), g
    assert g["CAIS-Base"] > 1.3                      # ablation matters
    assert g["SP-NVLS"] > g["TP-NVLS"]               # paper's ordering
    assert g["CoCoNet"] > g["CoCoNet-NVLS"]          # NVLS helps baselines
    assert g["FuseLib"] > g["FuseLib-NVLS"]
    assert g["T3"] > g["T3-NVLS"]
    assert g["LADM"] > 5.0                           # locality-only is far off


def test_bandwidth_utilization_ordering(fabric):
    """Fig. 15: CAIS-Base < CAIS-Partial < CAIS (useful-byte utilization)."""
    utils = {}
    for pol in ("CAIS-Base", "CAIS-Partial", "CAIS"):
        mk, busy = ps.run_sublayer(ps.LLAMA_7B, ps.BASELINES[pol], fabric,
                                   which="L2")
        utils[pol] = ps.useful_utilization(ps.BASELINES[pol], busy, mk)
    assert utils["CAIS-Base"] < utils["CAIS-Partial"] <= utils["CAIS"] + 1e-9
    assert utils["CAIS"] > 0.6


def test_merge_table_sensitivity(fabric):
    """Fig. 14: CAIS holds performance at small staging buffers (chunked),
    the uncoordinated version degrades as the buffer shrinks."""
    t_small = ps.run_model(ps.LLAMA_7B, ps.BASELINES["CAIS"], fabric,
                           chunks=32)   # small per-step buffer
    t_big = ps.run_model(ps.LLAMA_7B, ps.BASELINES["CAIS"], fabric, chunks=2)
    assert t_small <= t_big * 1.15
    base_small = ps.run_model(ps.LLAMA_7B, ps.BASELINES["CAIS-Base"], fabric,
                              chunks=32)
    assert base_small > t_small * 1.2


def test_scalability(fabric):
    """Fig. 17: per-device throughput within ~10% from 8 to 32 devices when
    the model scales with the ring (weak scaling)."""
    import dataclasses
    base = None
    for n in (8, 16, 32):
        cfg = dataclasses.replace(
            ps.LLAMA_7B, hidden=ps.LLAMA_7B.hidden * n // 8,
            ffn_hidden=ps.LLAMA_7B.ffn_hidden * n // 8)
        f = dataclasses.replace(fabric, n=n)
        t = ps.run_model(cfg, ps.BASELINES["CAIS"], f)
        thr = cfg.layers / t / n  # per-device work rate (arbitrary units)
        work = 1.0 * n  # flops grow ∝ hidden — normalize per device
        rate = work / t
        if base is None:
            base = rate
        assert rate >= 0.85 * base, (n, rate, base)
