"""Graph validation errors must name the offending node/value — a malformed
block graph should fail loudly at build/optimize time, not loop or KeyError
deep inside a pass (ISSUE 2 satellite)."""
import pytest

from repro.core import dataflow as df


def test_unknown_op_names_node():
    with pytest.raises(df.GraphError, match="bogus"):
        df.Node("n1", "bogus")
    with pytest.raises(df.GraphError, match="n1"):
        df.Node("n1", "not-an-op")


def test_cycle_names_nodes():
    nodes = [
        df.Node("x", "input"),
        df.Node("a", "add", ("x", "b")),
        df.Node("b", "add", ("x", "a")),
    ]
    g = df.Graph(nodes, outputs=("a",))
    with pytest.raises(df.GraphError, match="cycle") as ei:
        g.validate()
    assert "a" in str(ei.value) and "b" in str(ei.value)


def test_missing_producer_names_node_and_value():
    nodes = [
        df.Node("x", "input"),
        df.Node("g1", "gemm_col", ("nowhere",), ("w1",)),
    ]
    g = df.Graph(nodes, outputs=("g1",))
    with pytest.raises(df.GraphError, match="'g1'.*'nowhere'"):
        g.validate()


def test_missing_producer_caught_by_optimize():
    """optimize() re-topo-sorts after every rewrite — a dangling input must
    surface as a GraphError there too, not an opaque KeyError."""
    nodes = [
        df.Node("x", "input"),
        df.Node("g1", "gemm_row", ("missing",), ("w1",)),
        df.Node("rs", "reduce_scatter", ("g1",)),
    ]
    with pytest.raises(df.GraphError, match="missing"):
        df.optimize(df.Graph(nodes, outputs=("rs",)))


def test_unknown_graph_output():
    g = df.Graph([df.Node("x", "input")], outputs=("ghost",))
    with pytest.raises(df.GraphError, match="ghost"):
        g.validate()


def test_duplicate_producer():
    nodes = [
        df.Node("x", "input"),
        df.Node("a", "layernorm", ("x",), ("s",)),
        df.Node("dup", "add", ("x", "x"), outputs=("a",)),
    ]
    g = df.Graph(nodes, outputs=("a",))
    with pytest.raises(df.GraphError, match="'a'"):
        g.validate()


def test_validate_passes_and_returns_graph():
    g = df.sublayer_graph()
    assert g.validate() is g


def test_indexed_queries_match_scan_semantics():
    """node_producing/consumers now run off the shared adjacency index —
    pin their semantics (incl. multi-output fused nodes)."""
    g = df.optimize(df.sublayer_graph())
    fused = [n for n in g.nodes if n.op == "fused_rs_ln_ag"][0]
    for value in fused.outputs:
        assert g.node_producing(value) is fused
    assert g.node_producing("no-such-value") is None
    assert g.consumers("no-such-value") == []
    g2 = df.sublayer_graph()
    assert [n.name for n in g2.consumers("ln")] == ["ag"]
    assert g2.node_producing("ln").name == "ln"
