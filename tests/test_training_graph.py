"""Differentiable period graphs (ISSUE 7): the backward pass is ITSELF a
dataflow graph — every forward op in the post-pass-2 vocabulary has a
declared adjoint (``df.ADJOINTS``), ``build_training_graph`` appends the
emitted adjoints to the forward so ``optimize()`` sees ONE merged fwd+bwd
graph, and pass 3 can pair a backward grad reduce-scatter against an
independent chain's forward gather (the cross-direction ``overlap_asym``
the paper targets).

Covered here, all on the single-device reference path (``axis=None``
execution — collectives are identity, so the adjoints reduce to plain
linear algebra): per-op adjoint parity vs ``jax.vjp`` of the UNOPTIMIZED
forward graph, whole-period parity (dx + every dw), optimize() value
preservation on the training graph, the cross fwd/bwd pairing acceptance
property, ``supports_backward`` gating, derived ``"w^T"`` weight
materialization — plus the consolidated TP API surface that rides along
(``TPConfig`` deprecation shims, ``SPOptions`` keyword unification).

Multi-device gradient parity (train-step grads vs autodiff-of-unsplit on
the 4-way ring, per backend, incl. remat) lives in multidev_checks.py.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import tp
from repro.runtime import Runtime, TPConfig


def _toy_core(q, k, v):
    return q * jax.nn.sigmoid(k) + v


def _period_weights(key, n_blocks=2, d=16, f=24):
    w = {}
    for i in range(n_blocks):
        p = f"b{i}."
        ks = jax.random.split(jax.random.fold_in(key, i), 9)
        w[p + "scale1"] = jax.random.normal(ks[0], (d,)) * 0.1 + 1.0
        for j, kk in enumerate(("wq", "wk", "wv", "wo")):
            w[p + kk] = jax.random.normal(ks[1 + j], (d, d)) * 0.1
        w[p + "scale2"] = jax.random.normal(ks[5], (d,)) * 0.1 + 1.0
        w[p + "w_up"] = jax.random.normal(ks[6], (d, f)) * 0.1
        w[p + "w_gate"] = jax.random.normal(ks[7], (d, f)) * 0.1
        w[p + "w_down"] = jax.random.normal(ks[8], (f, d)) * 0.1
    return w


def _pass2(g):
    """The forward pipeline sp_period feeds the backward builder."""
    return df.fuse_sublayer_chain(df.fuse_shared_gather(
        df.fuse_compute_aware(g)))


def _graph_grads(g2, weights, vals, gys, norm="rmsnorm", optimize=False):
    """dx/dw through the graph-built backward (reference-path execution)."""
    tg = df.build_training_graph(g2, norm=norm)
    bwd = df.optimize(tg.graph) if optimize else tg.graph
    env = dict(vals)
    env.update(dict(zip(tg.grad_inputs, gys)))
    res = df.execute(bwd, env, df.derived_weights(bwd, weights))
    got = dict(zip(bwd.outputs, res))
    dx = {v: got[g_] for v, g_ in tg.dx.items()}
    dw = {}
    for k, parts in tg.dweights.items():
        acc = got[parts[0]]
        for p_ in parts[1:]:
            acc = acc + got[p_]
        dw[k] = acc
    return dx, dw


def _ref_grads(g, weights, vals, gys):
    """jax.vjp of the UNOPTIMIZED forward graph (reference execution)."""
    names = sorted(vals)

    def f(xs, w):
        return tuple(df.execute(g, dict(zip(names, xs)), w))

    _, pull = jax.vjp(f, tuple(vals[k] for k in names), weights)
    dxs, dw = pull(tuple(gys))
    return dict(zip(names, dxs)), dw


def _assert_grads_match(g, g2, weights, vals, norm="rmsnorm"):
    outs = df.execute(g, vals, weights)
    gys = [jnp.cos(jnp.arange(o.size, dtype=o.dtype)).reshape(o.shape) * 0.3
           for o in outs]
    dx_r, dw_r = _ref_grads(g, weights, vals, gys)
    for optimize in (False, True):
        dx_g, dw_g = _graph_grads(g2, weights, vals, gys, norm=norm,
                                  optimize=optimize)
        assert set(dx_g) == {k for k, v in dx_r.items()
                             if np.abs(np.asarray(v)).max() > 0} \
            or set(dx_g) == set(dx_r)
        for k in dx_g:
            np.testing.assert_allclose(np.asarray(dx_g[k]),
                                       np.asarray(dx_r[k]), atol=1e-5,
                                       err_msg=f"dx[{k}] opt={optimize}")
        for k in weights:
            np.testing.assert_allclose(np.asarray(dw_g[k]),
                                       np.asarray(dw_r[k]), atol=1e-5,
                                       err_msg=f"dw[{k}] opt={optimize}")


# ---------------------------------------------------------------------------
# per-op adjoints vs jax.vjp of the unoptimized graph
# ---------------------------------------------------------------------------


def test_adjoint_ag_gemm():
    """ag_gemm ↔ grad reduce-scatter through w^T + re-gathered dw."""
    d, f = 8, 12
    g = df.Graph([df.Node("x", "input"),
                  df.Node("y", "ag_gemm", ("x",), ("w",))], ("y",))
    w = {"w": jax.random.normal(jax.random.key(0), (d, f)) * 0.3}
    x = jax.random.normal(jax.random.key(1), (2, 6, d))
    _assert_grads_match(g, g, w, {"x": x})


def test_adjoint_ag_gemm_multi():
    """Shared gather: one concat cotangent reduce-scatters through the
    concatenated transposed weight ("wa+wb^T")."""
    d, f = 8, 12
    g = df.Graph([df.Node("x", "input"),
                  df.Node("qkv", "ag_gemm_multi", ("x",), ("wa", "wb"),
                          outputs=("ya", "yb"))], ("ya", "yb"))
    key = jax.random.key(2)
    w = {"wa": jax.random.normal(jax.random.fold_in(key, 0), (d, f)) * 0.3,
         "wb": jax.random.normal(jax.random.fold_in(key, 1), (d, f)) * 0.3}
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 6, d))
    _assert_grads_match(g, g, w, {"x": x})


def test_adjoint_gemm_rs():
    """gemm_rs ↔ grad all-gather (bwd_ag_gemm): dx through w^T plus the
    full-cotangent leg feeding dw."""
    d, f = 8, 12
    g = df.Graph([df.Node("h", "input"),
                  df.Node("y", "gemm_rs", ("h",), ("w",))], ("y",))
    w = {"w": jax.random.normal(jax.random.key(3), (f, d)) * 0.3}
    h = jax.random.normal(jax.random.key(4), (2, 6, f))
    _assert_grads_match(g, g, w, {"h": h})


def test_adjoint_fused_seam():
    """fused_rs_ln_ag (pass-2 seam) has a fused adjoint: grad RS through the
    gather leg, norm VJP on the re-exposed z, grad AG back through the RS
    leg — pinned against jax.vjp of the unoptimized sub-layer graph."""
    g = df.sublayer_graph()
    g2 = _pass2(g)
    assert any(n.op == "fused_rs_ln_ag" for n in g2.nodes)
    d, f = 10, 14
    key = jax.random.key(5)
    w = {"w1": jax.random.normal(jax.random.fold_in(key, 0), (d, f)) * 0.3,
         "scale": jax.random.normal(jax.random.fold_in(key, 1), (f,)) * 0.1
         + 1.0,
         "w2": jax.random.normal(jax.random.fold_in(key, 2), (f, d)) * 0.3}
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 4, d))
    _assert_grads_match(g, g2, w, {"x": x})


def test_adjoint_dense_period():
    """Whole 2-block dense period: every weight gradient and dx through the
    graph-built backward matches autodiff of the unoptimized period. The IR
    norm is scale-only (rmsnorm) — layernorm archs never reach the graph
    path (``_whole_block_applicable`` gates on ``cfg.norm``)."""
    g = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    w = _period_weights(jax.random.key(6))
    x = jax.random.normal(jax.random.key(7), (2, 8, 16))
    _assert_grads_match(g, _pass2(g), w, {"x": x}, norm="rmsnorm")


def test_adjoint_microbatch_chains_share_weights():
    """Two merged microbatch chains: each chain contributes one dw per use
    and the summed group equals autodiff of the merged graph."""
    base = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    g = tp.microbatch_period_graph(base, 2)
    w = _period_weights(jax.random.key(8))
    key = jax.random.key(9)
    vals = {"mb0.x": jax.random.normal(jax.random.fold_in(key, 0),
                                       (1, 8, 16)),
            "mb1.x": jax.random.normal(jax.random.fold_in(key, 1),
                                       (1, 8, 16))}
    _assert_grads_match(g, _pass2(g), w, vals)


# ---------------------------------------------------------------------------
# structure: merged fwd+bwd graph, cross-direction pass 3, gating
# ---------------------------------------------------------------------------


def _bwd_component(name):
    return "adj." in name or name.startswith(("d.", "dsum", "dcat.",
                                              "dfull.", "dznorm.", "dz.",
                                              "xg.", "zg.", "znr."))


def test_cross_direction_overlap_asym():
    """Acceptance (ISSUE 7): the optimized merged fwd/bwd graph of a 2-chain
    microbatch period contains ≥1 overlap_asym spanning a FORWARD node of
    one chain and a BACKWARD node of another — pass 3 ranks cross-direction
    pairs first on training graphs."""
    base = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    g2 = _pass2(tp.microbatch_period_graph(base, 2))
    tg = df.build_training_graph(g2)
    opt = df.optimize(tg.graph)
    pairs = [n for n in opt.nodes if n.op == "overlap_asym"]
    assert pairs, [(n.name, n.op) for n in opt.nodes]
    cross = [n for n in pairs
             if len({_bwd_component(s) for s in n.name.split("+")}) == 2]
    assert cross, [n.name for n in pairs]


def test_training_graph_optimize_idempotent():
    base = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    tg = df.build_training_graph(_pass2(tp.microbatch_period_graph(base, 2)))
    opt = df.optimize(tg.graph)
    assert [(n.name, n.op) for n in opt.nodes] == \
        [(n.name, n.op) for n in df.optimize(opt).nodes]


def test_forward_only_pairing_unchanged():
    """The cross-direction preference must NOT disturb forward-only graphs:
    the PR-4/5 pinned pairing decision stays bit-identical."""
    mk = lambda: tp.dense_block_graph(_toy_core, True, "silu")
    opt = df.optimize(df.merge_graphs([mk(), mk()], share_weights=True))
    pairs = [n for n in opt.nodes if n.op == "overlap_asym"]
    assert [n.name for n in pairs] == ["mb0.rs2+mb1.q+mb1.k+mb1.v"]


def test_supports_backward_gating():
    """Ops without a declared adjoint gate the graph backward off;
    build_training_graph refuses them loudly. Since PR 10 the replicated
    decode layout (gemm_col/gemm_ar) and the MoE ops (route/a2a_ffn/unroute)
    are IN the vocabulary — only raw collectives and pass-3 outputs gate."""
    g = tp.dense_period_graph([_toy_core, _toy_core], True, "silu")
    assert df.supports_backward(_pass2(g))
    g_ar = df.Graph([df.Node("x", "input"),
                     df.Node("y", "gemm_ar", ("x",), ("w",))], ("y",))
    assert df.supports_backward(g_ar)
    g_raw = df.Graph([df.Node("x", "input"),
                      df.Node("y", "allreduce", ("x",))], ("y",))
    assert not df.supports_backward(g_raw)
    with pytest.raises(df.GraphError, match="supports_backward"):
        df.build_training_graph(g_raw)
    # pass-3 output (overlap_asym) is also out of vocabulary: the backward
    # is built from the PRE-pass-3 graph, then optimized as one
    opt = df.optimize(df.dual_sublayer_graph())
    assert not df.supports_backward(opt)


def test_derived_weights_transpose_and_concat():
    d, f = 6, 8
    g = df.Graph([df.Node("x", "input"),
                  df.Node("qkv", "ag_gemm_multi", ("x",), ("wa", "wb"),
                          outputs=("ya", "yb"))], ("ya", "yb"))
    tg = df.build_training_graph(g)
    keys = df.derived_weight_keys(tg.graph)
    assert "wa+wb^T" in keys
    wa = jnp.arange(d * f, dtype=jnp.float32).reshape(d, f)
    wb = -wa
    ext = df.derived_weights(tg.graph, {"wa": wa, "wb": wb})
    np.testing.assert_array_equal(
        np.asarray(ext["wa+wb^T"]),
        np.asarray(jnp.concatenate([wa, wb], axis=-1).T))
    shapes = df.derived_weight_shapes(tg.graph, {"wa": (d, f), "wb": (d, f)})
    assert shapes["wa+wb^T"] == (2 * f, d)


# ---------------------------------------------------------------------------
# consolidated TP API surface: TPConfig shims + SPOptions
# ---------------------------------------------------------------------------


def test_runtime_legacy_kwargs_warn_and_forward():
    with pytest.warns(DeprecationWarning, match="tp_mode"):
        rt = Runtime(tp_mode="cais", cais_chunks=4)
    assert rt.tp.mode == "cais" and rt.tp.chunks == 4
    with pytest.warns(DeprecationWarning, match="tp_microbatches"):
        rt = Runtime(tp_microbatches=2, tp_planner="perfsim")
    assert rt.tp.microbatches == 2 and rt.tp.planner == "perfsim"
    # legacy kwargs fold INTO an explicit tp= base, not over it
    with pytest.warns(DeprecationWarning, match="cais_bidirectional"):
        rt = Runtime(tp=TPConfig(mode="cais"), cais_bidirectional=False)
    assert rt.tp.mode == "cais" and rt.tp.bidirectional is False


def test_runtime_legacy_properties_warn_and_read_through():
    rt = Runtime(tp=TPConfig(mode="barrier", chunks=8, microbatches=2,
                             planner="perfsim", sequence_parallel=False,
                             bidirectional=False))
    for name, want in (("tp_mode", "barrier"), ("cais_chunks", 8),
                       ("tp_microbatches", 2), ("tp_planner", "perfsim"),
                       ("sequence_parallel", False),
                       ("cais_bidirectional", False)):
        with pytest.warns(DeprecationWarning, match=name):
            assert getattr(rt, name) == want


def test_runtime_unknown_kwarg_still_raises():
    with pytest.raises(TypeError, match="tp_bogus"):
        Runtime(tp_bogus=1)


def test_tpcontext_from_config_single_path():
    from repro import sharding

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    cfgtp = TPConfig(mode="cais", chunks=4, bidirectional=False,
                     microbatches=2, planner="perfsim",
                     graph_backward=False)
    tpc = tp.TPContext.from_config(cfgtp, mesh)
    assert tpc.backend.name == "cais"   # resolved to the registry instance
    assert tpc.cais.num_chunks == 4 and tpc.cais.bidirectional is False
    assert tpc.num_microbatches == 2 and tpc.planner == "perfsim"
    assert tpc.graph_backward is False


def _mini_setup():
    import repro.models.transformer as tr
    from repro import sharding
    from repro.configs import get_arch
    from repro.core.primitives import CAISConfig

    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=48)
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais",
                       cais=CAISConfig(num_chunks=1))
    params = tr.init_block(jax.random.key(11), "attn", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(12), (2, 16, 32), jnp.float32)
    return tpc, x, params, cfg


def test_sp_options_object_equals_keywords():
    """sp_block/sp_period accept the shared SPOptions object; the options
    path and the keyword path are the same call."""
    tpc, x, params, cfg = _mini_setup()
    a, _ = tp.sp_block(tpc, x, params, cfg, "attn", norm_kind=cfg.norm)
    b, _ = tp.sp_block(tpc, x, params, cfg, "attn",
                       opts=tp.SPOptions(norm_kind=cfg.norm))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c, _ = tp.sp_period(tpc, x, (params,), cfg, ("attn",),
                        opts=tp.SPOptions(norm_kind=cfg.norm))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_sp_options_unknown_keyword_raises():
    tpc, x, params, cfg = _mini_setup()
    with pytest.raises(TypeError, match="bogus"):
        tp.sp_block(tpc, x, params, cfg, "attn", bogus=1)


def test_sp_period_grad_matches_autodiff_single_device():
    """End-to-end on the tp=1 mesh: grads of a scalar loss through
    sp_period's custom VJP match the graph_backward=False autodiff path."""
    import dataclasses as _dc

    tpc, x, params, cfg = _mini_setup()
    tpc_ref = _dc.replace(tpc, graph_backward=False)

    def loss(tpc_):
        def f(x_, p_):
            out, _ = tp.sp_period(tpc_, x_, (p_,), cfg, ("attn",),
                                  norm_kind=cfg.norm)
            return jnp.sum(out * out)
        return jax.grad(f, argnums=(0, 1))(x, params)

    g_vjp = loss(tpc)
    g_ref = loss(tpc_ref)
    for a, b in zip(jax.tree.leaves(g_vjp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
