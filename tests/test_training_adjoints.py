"""Property-based grad-parity harness for the CLOSED backward vocabulary
(ISSUE 10): every op the model path can leave in a post-pass-2 period graph
— MoE routing (``route``/``a2a_ffn``/``unroute``, aux-loss side-output
included), the replicated-activation decode/ragged layout
(``gemm_col``/``gemm_ar``, S=1 included) — has a declared adjoint whose
graph-built backward matches ``jax.vjp`` of the UNOPTIMIZED forward graph
to 1e-6, with ``optimize()`` both off and on.

All on the single-device reference path (``axis=None`` — collectives are
identity), swept by ``_hypothesis_compat`` strategies over expert count,
capacity factor, a2a ring factorization (the ring dim of the mesh the
graph is built for: 1×8 → ring 8, 2×4 → ring 2 grouped EP, 8×1 → ring 1;
mesh-free runs execute the per-owner LOCAL view, so expert weights carry
the E_loc = E/ring shard shape), ragged sequence lengths down to S=1, and
microbatch count. True multi-device parity for the same cells lives in
``multidev_checks.py`` (``train_grad.graph_vs_autodiff.moe.*``,
``train_grad.decode_gemm_ar.*``).
"""
import types

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.configs.base import MoEConfig
from repro.core import dataflow as df
from repro.core import tp

ATOL = 1e-6


def _toy_core(q, k, v):
    return q * jax.nn.sigmoid(k) + v


def _pass2(g):
    return df.fuse_sublayer_chain(df.fuse_shared_gather(
        df.fuse_compute_aware(g)))


def _moe_fns(E, cap, ring, has_gate=True, act="silu"):
    cfg = types.SimpleNamespace(
        act=act, moe=MoEConfig(num_experts=E, top_k=2, capacity_factor=cap))
    return tp._moe_graph_fns(cfg, ring, has_gate)


def _graph_grads(g2, weights, vals, gys, optimize=False):
    tg = df.build_training_graph(g2, norm="rmsnorm")
    bwd = df.optimize(tg.graph) if optimize else tg.graph
    env = dict(vals)
    env.update(dict(zip(tg.grad_inputs, gys)))
    res = df.execute(bwd, env, df.derived_weights(bwd, weights))
    got = dict(zip(bwd.outputs, res))
    dx = {v: got[g_] for v, g_ in tg.dx.items()}
    dw = {}
    for k, parts in tg.dweights.items():
        acc = got[parts[0]]
        for p_ in parts[1:]:
            acc = acc + got[p_]
        dw[k] = acc
    return dx, dw


def _ref_grads(g, weights, vals, gys):
    names = sorted(vals)

    def f(xs, w):
        return tuple(df.execute(g, dict(zip(names, xs)), w))

    _, pull = jax.vjp(f, tuple(vals[k] for k in names), weights)
    dxs, dw = pull(tuple(gys))
    return dict(zip(names, dxs)), dw


def _check(g, g2, weights, vals):
    """graph-built backward of g2 ≡ jax.vjp of the unoptimized g, ≤1e-6,
    with the training graph optimize()d both off and on."""
    outs = df.execute(g, vals, weights)
    gys = [jnp.cos(jnp.arange(o.size, dtype=jnp.float32)
                   ).reshape(o.shape).astype(o.dtype) * 0.3 for o in outs]
    dx_r, dw_r = _ref_grads(g, weights, vals, gys)
    for optimize in (False, True):
        dx_g, dw_g = _graph_grads(g2, weights, vals, gys, optimize=optimize)
        for k in dx_g:
            np.testing.assert_allclose(
                np.asarray(dx_g[k]), np.asarray(dx_r[k]), atol=ATOL,
                err_msg=f"dx[{k}] opt={optimize}")
        for k in weights:
            np.testing.assert_allclose(
                np.asarray(dw_g[k]), np.asarray(dw_r[k]), atol=ATOL,
                err_msg=f"dw[{k}] opt={optimize}")


def _key(*ints):
    k = jax.random.key(20)
    for i in ints:
        k = jax.random.fold_in(k, i)
    return k


# ---------------------------------------------------------------------------
# per-op adjoints
# ---------------------------------------------------------------------------


@given(E=st.sampled_from([2, 4]), cap=st.floats(1.0, 2.0),
       S=st.sampled_from([1, 5]))
def test_adjoint_route(E, cap, S):
    """route ⇒ jax.vjp of the routing closure: the combine-weighted grad
    scatter AND the aux-loss statistic's cotangent feeding the router
    logits (through the differentiable density_proxy only — the one-hot
    density factor is piecewise-constant, so this IS the straight-through
    convention)."""
    d = 8
    route_fn, _, _ = _moe_fns(E, cap, ring=1)
    g = df.Graph([df.Node("x", "input"),
                  df.Node("rt", "route", ("x",), ("router",),
                          outputs=("send", "combine", "aux"), fn=route_fn)],
                 ("send", "combine", "aux"))
    w = {"router": jax.random.normal(_key(0, E), (d, E),
                                     jnp.float32) * 0.5}
    x = jax.random.normal(_key(1, E, S), (2, S, d), jnp.float32)
    _check(g, g, w, {"x": x})


@given(E=st.sampled_from([2, 4]), ring=st.sampled_from([1, 2]),
       gate=st.booleans())
def test_adjoint_a2a_ffn(E, ring, gate):
    """a2a_ffn ⇒ bwd_a2a_ffn: per-row VJP of the expert FFN with
    expert-weight grads summed over the ring rows (the reference-path
    analogue of keeping dw on the owner). ring>1 runs the per-owner local
    view: E_loc = E/ring experts per row, shard-shaped weights."""
    d, f, cap = 6, 10, 3
    E_loc = E // ring
    _, expert_fn, _ = _moe_fns(E, 1.5, ring, has_gate=gate)
    wk = ("w_up",) + (("w_gate",) if gate else ()) + ("w_down",)
    g = df.Graph([df.Node("send", "input"),
                  df.Node("eout", "a2a_ffn", ("send",), wk, fn=expert_fn)],
                 ("eout",))
    w = {"w_up": jax.random.normal(_key(2, E, ring), (E_loc, d, f)) * 0.3,
         "w_down": jax.random.normal(_key(3, E, ring), (E_loc, f, d)) * 0.3}
    if gate:
        w["w_gate"] = jax.random.normal(_key(4, E, ring),
                                        (E_loc, d, f)) * 0.3
    send = jax.random.normal(_key(5, E, ring), (ring, E_loc * cap, d))
    _check(g, g, w, {"send": send})


@given(E=st.sampled_from([2, 4]), cap=st.floats(1.0, 2.0))
def test_adjoint_unroute(E, cap):
    """unroute ⇒ the route adjoint's dual: cotangents scatter back through
    the combine weights into both the expert outputs and the combine tensor
    (xn is shape-only — its cotangent is exactly zero)."""
    d, S = 8, 4
    route_fn, _, unroute_fn = _moe_fns(E, cap, ring=1)
    g = df.Graph([df.Node("xn", "input"),
                  df.Node("rt", "route", ("xn",), ("router",),
                          outputs=("send", "combine", "aux"), fn=route_fn),
                  df.Node("eout", "input"),
                  df.Node("y", "unroute", ("eout", "combine", "xn"),
                          fn=unroute_fn)],
                 ("y", "aux"))
    w = {"router": jax.random.normal(_key(6, E), (d, E), jnp.float32) * 0.5}
    xn = jax.random.normal(_key(7, E), (2, S, d), jnp.float32)
    T = 2 * S
    capn = max(1, int(T * 2 / E * cap))
    eout = jax.random.normal(_key(8, E), (1, E * capn, d), jnp.float32)
    _check(g, g, w, {"xn": xn, "eout": eout})


@given(S=st.sampled_from([1, 3, 6]), gate=st.booleans())
def test_adjoint_decode_block(S, gate):
    """The sequence_parallel=False (replicated-activation decode/ragged)
    layout: pass 1 leaves raw gemm_col and fuses gemm_row+allreduce into
    gemm_ar — both now in the adjoint vocabulary, S=1 included, so
    graph_backward no longer silently excludes decode-shaped periods."""
    d, f = 8, 12
    nodes, out = tp._dense_block_nodes(_toy_core, gate, "silu",
                                       seq_sharded=False)
    g = df.Graph([df.Node("x", "input")] + nodes, (out,))
    g2 = _pass2(g)
    assert any(n.op == "gemm_ar" for n in g2.nodes)
    assert any(n.op == "gemm_col" for n in g2.nodes)
    w = {"scale1": jax.random.normal(_key(9, S), (d,)) * 0.1 + 1.0,
         "scale2": jax.random.normal(_key(10, S), (d,)) * 0.1 + 1.0,
         "w_up": jax.random.normal(_key(11, S), (d, f)) * 0.3,
         "w_down": jax.random.normal(_key(12, S), (f, d)) * 0.3}
    for i, kk in enumerate(("wq", "wk", "wv", "wo")):
        w[kk] = jax.random.normal(_key(13 + i, S), (d, d)) * 0.3
    if gate:
        w["w_gate"] = jax.random.normal(_key(17, S), (d, f)) * 0.3
    x = jax.random.normal(_key(18, S), (2, S, d))
    _check(g, g2, w, {"x": x})


# ---------------------------------------------------------------------------
# whole-period property: MoE block graph, optimize off+on, microbatched
# ---------------------------------------------------------------------------


def _moe_block_setup(E, cap, ring, S, key0):
    d, f = 8, 12
    E_loc = E // ring
    route_fn, expert_fn, unroute_fn = _moe_fns(E, cap, ring)
    g = tp.moe_block_graph(_toy_core, route_fn, expert_fn, unroute_fn,
                           ("w_up", "w_gate", "w_down"), True)
    w = {"scale1": jax.random.normal(_key(key0, 0), (d,)) * 0.1 + 1.0,
         "scale2": jax.random.normal(_key(key0, 1), (d,)) * 0.1 + 1.0,
         "router": jax.random.normal(_key(key0, 2), (d, E),
                                     jnp.float32) * 0.5,
         "w_up": jax.random.normal(_key(key0, 3), (E_loc, d, f)) * 0.3,
         "w_gate": jax.random.normal(_key(key0, 4), (E_loc, d, f)) * 0.3,
         "w_down": jax.random.normal(_key(key0, 5), (E_loc, f, d)) * 0.3}
    for i, kk in enumerate(("wq", "wk", "wv", "wo")):
        w[kk] = jax.random.normal(_key(key0, 6 + i), (d, d)) * 0.3
    x = jax.random.normal(_key(key0, 10), (2, S, d), jnp.float32)
    return g, w, x


@given(E=st.sampled_from([2, 4]), cap=st.floats(1.0, 2.0),
       ring=st.sampled_from([1, 2]), S=st.sampled_from([1, 4]),
       mb=st.sampled_from([1, 2]))
@settings(deadline=None, max_examples=24)
def test_moe_period_grad_parity(E, cap, ring, S, mb):
    """Whole MoE period (attention + route → a2a_ffn → unroute, pass-2
    fused_rs_ln seam included): dx + every dw + the aux cotangent through
    the graph-built backward ≡ jax.vjp of the unoptimized graph, swept
    over expert count × capacity factor × ring factorization × ragged S
    (S=1 included) × microbatch count, optimize() off AND on."""
    g, w, x = _moe_block_setup(E, cap, ring, S, key0=30 + mb)
    base = g
    vals = {"x": x}
    if mb > 1:
        g = tp.microbatch_period_graph(base, mb)
        vals = {f"mb{i}.x": jax.random.normal(_key(40 + i, E, ring, S),
                                              (1, S, 8), jnp.float32)
                for i in range(mb)}
    g2 = _pass2(g)
    assert any(n.op == "fused_rs_ln" for n in g2.nodes)
    assert any(n.op == "a2a_ffn" for n in g2.nodes)
    _check(g, g2, w, vals)


def test_moe_training_graph_structure():
    """The merged fwd+bwd MoE graph is ONE graph: the a2a_ffn adjoint is a
    first-class bwd_a2a_ffn node carrying the expert weights, the route
    adjoint consumes the aux cotangent seed, and supports_backward says so."""
    g, _, _ = _moe_block_setup(4, 1.5, 1, 4, key0=50)
    g2 = _pass2(g)
    assert df.supports_backward(g2)
    tg = df.build_training_graph(g2, norm="rmsnorm")
    assert "d.aux" in tg.grad_inputs
    bwd = [n for n in tg.graph.nodes if n.op == "bwd_a2a_ffn"]
    assert len(bwd) == 1
    assert bwd[0].weights == ("w_up", "w_gate", "w_down")
    # every expert weight has a gradient group
    for k in ("w_up", "w_gate", "w_down", "router"):
        assert k in tg.dweights, sorted(tg.dweights)


def _bwd_component(name):
    return "adj." in name or name.startswith(("d.", "dsum", "dcat.",
                                              "dfull.", "dznorm.", "dz.",
                                              "xg.", "zg.", "znr."))


def test_moe_cross_direction_overlap_asym():
    """Acceptance: the optimized merged fwd+bwd graph of a 2-microbatch MoE
    period contains ≥1 overlap_asym spanning a FORWARD node of one chain and
    a BACKWARD node of the other — the planner can overlap mb1's backward
    grad collectives against mb0's forward gathers."""
    g, _, _ = _moe_block_setup(4, 1.5, 1, 4, key0=70)
    g2 = _pass2(tp.microbatch_period_graph(g, 2))
    tg = df.build_training_graph(g2, norm="rmsnorm")
    opt = df.optimize(tg.graph)
    pairs = [n for n in opt.nodes if n.op == "overlap_asym"]
    assert pairs, [(n.name, n.op) for n in opt.nodes]
    cross = [n for n in pairs
             if len({_bwd_component(s) for s in n.name.split("+")}) == 2]
    assert cross, [n.name for n in pairs]


# ---------------------------------------------------------------------------
# fallback gate: warn once naming the offending ops, stay parity-exact
# ---------------------------------------------------------------------------


def _moe_mini_setup():
    import dataclasses as _dc

    import repro.models.transformer as tr
    from repro import sharding
    from repro.configs import get_arch
    from repro.core.primitives import CAISConfig

    cfg = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=1, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=48)
    cfg = cfg.scaled(moe=_dc.replace(cfg.moe, capacity_factor=8.0))
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    tpc = tp.TPContext(mesh=mesh, backend="cais",
                       cais=CAISConfig(num_chunks=1))
    params = tr.init_block(jax.random.key(60), "attn", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(61), (2, 16, 32), jnp.float32)
    return tpc, x, params, cfg


def test_moe_sp_period_grad_matches_autodiff_single_device():
    """End-to-end on the tp=1 mesh: MoE period grads (incl. the aux-loss
    term) through sp_period's graph-built custom VJP match the
    graph_backward=False autodiff path."""
    import dataclasses as _dc

    tpc, x, params, cfg = _moe_mini_setup()

    def grads(tpc_):
        def f(x_, p_):
            out, aux = tp.sp_period(tpc_, x_, (p_,), cfg, ("attn",),
                                    norm_kind=cfg.norm)
            return jnp.sum(out * out) + aux
        return jax.grad(f, argnums=(0, 1))(x, params)

    g_vjp = grads(tpc)
    g_ref = grads(_dc.replace(tpc, graph_backward=False))
    for a, b in zip(jax.tree.leaves(g_vjp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_graph_backward_fallback_warns_once(monkeypatch):
    """When graph_backward=True and a period fails the adjoint-vocabulary
    gate, sp_period warns ONCE naming the offending op(s) (it used to fall
    back silently) and the fallback matches graph_backward=False exactly."""
    import dataclasses as _dc
    import warnings as _warnings

    import pytest

    tpc, x, params, cfg = _moe_mini_setup()
    monkeypatch.delitem(df.ADJOINTS, "a2a_ffn")
    monkeypatch.setattr(tp, "_GRAPH_BWD_WARNED", set())

    def loss(tpc_):
        def f(x_, p_):
            out, aux = tp.sp_period(tpc_, x_, (p_,), cfg, ("attn",),
                                    norm_kind=cfg.norm)
            return jnp.sum(out * out) + aux
        return f(x, params), jax.grad(f, argnums=(0, 1))(x, params)

    with pytest.warns(UserWarning, match="a2a_ffn"):
        l_fb, g_fb = loss(tpc)
    # second qualification failure with the same op set: no second warning
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", UserWarning)
        loss(tpc)
    l_ref, g_ref = loss(_dc.replace(tpc, graph_backward=False))
    np.testing.assert_allclose(np.asarray(l_fb), np.asarray(l_ref),
                               atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_fb), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
