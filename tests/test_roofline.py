"""Tests for the while-aware HLO cost analyzer (the roofline's foundation).

XLA's cost_analysis counts scan bodies once; these tests pin the analyzer's
trip-count multiplication, dot flop formula, and collective accounting
against hand-computed ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analyzer import HLOAnalyzer, analyze
from repro.roofline.hlo_costs import roofline_terms


def _compile(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile().as_text()


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_flops_scale_with_trip_count(n):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    txt = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                   jax.ShapeDtypeStruct((256, 256), jnp.float32))
    r = analyze(txt)
    expect = n * 2 * 128 * 256 * 256
    assert expect <= r["flops"] <= 1.05 * expect, (n, r["flops"], expect)


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                   jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(txt)
    expect = 15 * 2 * 64 * 128 * 128
    assert expect <= r["flops"] <= 1.05 * expect


def test_raw_cost_analysis_undercounts_scans():
    """Documents WHY the analyzer exists."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    ca = compiled.cost_analysis()
    raw = (ca[0] if isinstance(ca, list) else ca)["flops"]
    true = analyze(compiled.as_text())["flops"]
    assert true > 10 * raw  # 16 trips counted once


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    txt = _compile(f, jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                   jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    r = analyze(txt)
    expect = 2 * 4 * 32 * 16 * 64
    assert expect <= r["flops"] <= 1.1 * expect


def test_memory_bytes_slice_aware():
    """dynamic-slice from a big buffer must count the slice, not the source."""
    def f(big, idx):
        def body(acc, i):
            sl = jax.lax.dynamic_slice_in_dim(big, i * 8, 8, axis=0)
            return acc + jnp.sum(sl), None
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(4))
        return out

    txt = _compile(f, jax.ShapeDtypeStruct((4096, 256), jnp.float32),
                   jax.ShapeDtypeStruct((), jnp.int32))
    r = analyze(txt)
    # 4 slices of 8×256 f32 (2× for r+w) + param read ≪ source size × trips
    source = 4096 * 256 * 4
    assert r["bytes"] < 3 * source, r["bytes"]


def test_collective_bytes_trip_multiplied():
    """A ppermute inside a scan must count once per trip (runs under 2
    forced host devices in the dedicated subprocess suite; here we only
    check the parser on synthetic HLO)."""
    hlo = """
HloModule m

%body (p: (s32[], f32[64,32])) -> (s32[], f32[64,32]) {
  %p = (s32[], f32[64,32]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,32]{1,0} get-tuple-element(%p), index=1
  %cp = f32[64,32]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[64,32]{1,0}) tuple(%ni, %cp)
}

%cond (p: (s32[], f32[64,32])) -> pred[] {
  %p = (s32[], f32[64,32]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[64,32]) {
  %x = f32[64,32]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[64,32]{1,0}) tuple(%c0, %x)
  %w = (s32[], f32[64,32]{1,0}) while(%t), condition=%cond, body=%body
  ROOT %o = f32[64,32]{1,0} get-tuple-element(%w), index=1
}
"""
    r = analyze(hlo)
    expect = 6 * 64 * 32 * 4  # 6 trips × payload
    assert r["coll_collective-permute"] == expect


def test_roofline_terms_dominance():
    r = roofline_terms(flops_dev=1e15, bytes_dev=1e9, coll_bytes_dev=1e9)
    assert r.dominant == "compute"
    r = roofline_terms(flops_dev=1e12, bytes_dev=1e13, coll_bytes_dev=1e9)
    assert r.dominant == "memory"
    r = roofline_terms(flops_dev=1e12, bytes_dev=1e9, coll_bytes_dev=1e12)
    assert r.dominant == "collective"
