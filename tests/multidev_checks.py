"""Multi-device correctness checks, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (so the main pytest
process keeps a single device; see tests/test_multidevice.py).

Prints one `CHECK <name> <maxerr>` line per assertion; exits non-zero on any
failure.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import get_arch
from repro.core import dataflow as df
from repro.core import primitives as prim
from repro.core.primitives import CAISConfig
from repro.models import build_model
from repro.runtime import Runtime, TPConfig

FAILED = []


def check(name, err, tol=1e-4):
    print(f"CHECK {name} {err:.3e}")
    if not (err <= tol):
        FAILED.append((name, err))


def main():
    assert len(jax.devices()) == 8, jax.devices()

    # ---------------- primitives on TP rings of size 2 / 4 / 8 ------------
    B, S, d, F = 2, 64, 32, 48
    x = jax.random.normal(jax.random.key(0), (B, S, d), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (d, F), jnp.float32) * 0.1
    ref = x @ w

    for ring in (2, 4, 8):
        rmesh = sharding.make_mesh((8 // ring, ring), ("data", "model"))
        cais = CAISConfig(num_chunks=2, bidirectional=True)
        y = jax.jit(sharding.shard_map(
            lambda xl, wl: prim.ag_gemm(xl, wl, "model", cais),
            mesh=rmesh, in_specs=(P(None, "model", None), P(None, "model")),
            out_specs=P(None, None, "model"), check_vma=False))(x, w)
        check(f"ag_gemm.ring{ring}", float(jnp.abs(y - ref).max()))
        y2 = jax.jit(sharding.shard_map(
            lambda xl, wl: prim.gemm_rs(xl, wl, "model", cais),
            mesh=rmesh, in_specs=(P(None, None, "model"), P("model", None)),
            out_specs=P(None, "model", None), check_vma=False))(x, w)
        check(f"gemm_rs.ring{ring}", float(jnp.abs(y2 - ref).max()))

    mesh = sharding.make_mesh((8,), ("model",))
    for chunks in (1, 2, 4):
        for bidir in (False, True):
            cais = CAISConfig(num_chunks=chunks, bidirectional=bidir)
            y = jax.jit(sharding.shard_map(
                lambda xl, wl: prim.ag_gemm(xl, wl, "model", cais),
                mesh=mesh, in_specs=(P(None, "model", None), P(None, "model")),
                out_specs=P(None, None, "model"), check_vma=False))(x, w)
            check(f"ag_gemm.c{chunks}.b{int(bidir)}",
                  float(jnp.abs(y - ref).max()))
            y2 = jax.jit(sharding.shard_map(
                lambda xl, wl: prim.gemm_rs(xl, wl, "model", cais),
                mesh=mesh, in_specs=(P(None, None, "model"), P("model", None)),
                out_specs=P(None, "model", None), check_vma=False))(x, w)
            check(f"gemm_rs.c{chunks}.b{int(bidir)}",
                  float(jnp.abs(y2 - ref).max()))

    cais = CAISConfig(num_chunks=2)
    y3 = jax.jit(sharding.shard_map(
        lambda xl, wl: prim.gemm_ar(xl, wl, "model", cais),
        mesh=mesh, in_specs=(P(None, None, "model"), P("model", None)),
        out_specs=P(None, None, None), check_vma=False))(x, w)
    check("gemm_ar", float(jnp.abs(y3 - ref).max()))

    x2 = jax.random.normal(jax.random.key(2), (B, S, d))
    w2 = jax.random.normal(jax.random.key(3), (d, F)) * 0.1
    o1, o2 = jax.jit(sharding.shard_map(
        lambda a, b, c, e: prim.overlap_asymmetric((a, b), (c, e), "model",
                                                   cais),
        mesh=mesh,
        in_specs=(P(None, None, "model"), P("model", None),
                  P(None, "model", None), P(None, "model")),
        out_specs=(P(None, "model", None), P(None, None, "model")),
        check_vma=False))(x, w, x2, w2)
    check("overlap_asym.rs", float(jnp.abs(o1 - ref).max()))
    check("overlap_asym.ag", float(jnp.abs(o2 - x2 @ w2).max()))

    # ---------------- dataflow optimizer ----------------
    g = df.sublayer_graph()
    opt = df.optimize(g)
    assert [n.op for n in opt.nodes if n.op != "input"] == ["fused_rs_ln_ag"]
    w1 = jax.random.normal(jax.random.key(4), (d, F)) * 0.1
    scale = jax.random.normal(jax.random.key(5), (F,)) * 0.1
    wu = jax.random.normal(jax.random.key(6), (F, d)) * 0.1
    refdf = df.execute(g, {"x": x}, {"w1": w1, "scale": scale, "w2": wu})[0]

    def run_graph(graph, backend="cais"):
        def local(x, w1, scale, w2):
            return df.execute(graph, {"x": x},
                              {"w1": w1, "scale": scale, "w2": w2},
                              axis="model", cais=cais, backend=backend)
        return jax.jit(sharding.shard_map(
            local, mesh=mesh,
            in_specs=(P(None, None, "model"), P("model", None), P(),
                      P(None, "model")),
            out_specs=(P(None, None, "model"),), check_vma=False))(
                x, w1, scale, wu)[0]

    check("dataflow.unopt", float(jnp.abs(run_graph(g) - refdf).max()), 1e-3)
    check("dataflow.opt", float(jnp.abs(run_graph(opt) - refdf).max()), 1e-3)
    check("dataflow.opt_barrier",
          float(jnp.abs(run_graph(opt, "barrier") - refdf).max()), 1e-3)

    # ---------------- graph-routed sub-layers vs hand-fused ---------------
    # sp_ffn / sp_attention now build + optimize + execute a dataflow graph;
    # pin them to the pre-refactor hand-fused schedules (written out inline
    # with the raw primitives) on a 4-way ring, per backend.
    from repro.core import tp as tp_mod
    from repro.core.primitives import CAISConfig as CC
    from repro.models.layers import activation, apply_norm

    mesh4 = sharding.make_mesh((2, 4), ("data", "model"))
    d_ff = 96
    ksub = jax.random.split(jax.random.key(20), 4)
    ns = jax.random.normal(ksub[0], (d,)) * 0.1 + 1.0
    wu4 = jax.random.normal(ksub[1], (d, d_ff)) * 0.1
    wg4 = jax.random.normal(ksub[2], (d, d_ff)) * 0.1
    wd4 = jax.random.normal(ksub[3], (d_ff, d)) * 0.1
    cais4 = CC(num_chunks=2)

    def hand_fused_ffn(mode):
        """The pre-refactor sp_ffn local body (tp.py@636bb1c)."""
        def local(x, ns, wu, wg, wd):
            xn = apply_norm("rmsnorm", {"scale": ns}, x)
            if mode == "barrier":
                h = prim.barrier_ag_gemm(xn, wu, "model")
                g_ = prim.barrier_ag_gemm(xn, wg, "model")
                h = activation("silu", g_) * h
                return prim.barrier_gemm_rs(h, wd, "model")
            outs = prim.ag_gemm_multi(xn, (wu, wg), "model", cais4)
            h = activation("silu", outs[1]) * outs[0]
            return prim.gemm_rs(h, wd, "model", cais4)
        return jax.jit(sharding.shard_map(
            local, mesh=mesh4,
            in_specs=(P(None, "model", None), P(None,), P(None, "model"),
                      P(None, "model"), P("model", None)),
            out_specs=P(None, "model", None), check_vma=False))(
                x, ns, wu4, wg4, wd4)

    for mode in ("barrier", "cais"):
        tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
        got = tp_mod.sp_ffn(tpc4, x, ns, wu4, wg4, wd4, "silu")
        check(f"sp_ffn.graph_vs_handfused.{mode}",
              float(jnp.abs(got - hand_fused_ffn(mode)).max()), 1e-5)
        # auto-planned chunking must agree with static chunking numerics
        tpc4p = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=CC())
        gotp = tp_mod.sp_ffn(tpc4p, x, ns, wu4, wg4, wd4, "silu")
        check(f"sp_ffn.planned_chunks.{mode}",
              float(jnp.abs(gotp - hand_fused_ffn(mode)).max()), 1e-5)

    from repro.models.attention import attention_core
    from repro.models.layers import apply_rope

    cfg_at = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=d_ff)
    kat = jax.random.split(jax.random.key(21), 4)
    wq4, wk4, wv4, wo4 = (jax.random.normal(k, (d, d)) * 0.1 for k in kat)
    H, dh = cfg_at.num_heads, cfg_at.resolved_head_dim

    def hand_fused_attn(mode):
        """The pre-refactor sp_attention local body (tp.py@636bb1c),
        dense-heads case (kv sharded)."""
        def local(x, ns, wq, wk, wv, wo):
            xn = apply_norm("rmsnorm", {"scale": ns}, x)
            if mode == "barrier":
                q = prim.barrier_ag_gemm(xn, wq, "model")
                k = prim.barrier_ag_gemm(xn, wk, "model")
                v = prim.barrier_ag_gemm(xn, wv, "model")
            else:
                q, k, v = prim.ag_gemm_multi(xn, (wq, wk, wv), "model", cais4)
            B_, S = q.shape[0], q.shape[1]
            H_loc = H // 4
            pos = jnp.broadcast_to(jnp.arange(S), (B_, S))
            q = apply_rope(q.reshape(B_, S, H_loc, dh), pos,
                           cfg_at.rope_theta)
            k = apply_rope(k.reshape(B_, S, H_loc, dh), pos,
                           cfg_at.rope_theta)
            v = v.reshape(B_, S, H_loc, dh)
            o = attention_core(q, k, v, q_positions=pos, kv_positions=pos,
                               causal=True)
            o = o.reshape(B_, S, H_loc * dh)
            if mode == "barrier":
                return prim.barrier_gemm_rs(o, wo, "model")
            return prim.gemm_rs(o, wo, "model", cais4)
        return jax.jit(sharding.shard_map(
            local, mesh=mesh4,
            in_specs=(P(None, "model", None), P(None,), P(None, "model"),
                      P(None, "model"), P(None, "model"), P("model", None)),
            out_specs=P(None, "model", None), check_vma=False))(
                x, ns, wq4, wk4, wv4, wo4)

    for mode in ("barrier", "cais"):
        tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
        got = tp_mod.sp_attention(tpc4, x, ns, wq4, wk4, wv4, wo4, cfg_at)
        check(f"sp_attention.graph_vs_handfused.{mode}",
              float(jnp.abs(got - hand_fused_attn(mode)).max()), 1e-5)

    # replicated-KV (GQA, Hkv < tp): K/V weights replicate and the custom
    # core slices per-device heads via axis_index — pin against a mesh-free
    # dense reference (attention_core handles grouped heads natively)
    cfg_gqa = cfg_at.scaled(num_kv_heads=2)
    kkv = jax.random.split(jax.random.key(22), 2)
    wk2 = jax.random.normal(kkv[0], (d, 2 * dh)) * 0.1
    wv2 = jax.random.normal(kkv[1], (d, 2 * dh)) * 0.1
    xn_full = apply_norm("rmsnorm", {"scale": ns}, x)
    pos_full = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                (x.shape[0], x.shape[1]))
    q_ref = apply_rope((xn_full @ wq4).reshape(x.shape[0], x.shape[1], H, dh),
                       pos_full, cfg_gqa.rope_theta)
    k_ref = apply_rope((xn_full @ wk2).reshape(x.shape[0], x.shape[1], 2, dh),
                       pos_full, cfg_gqa.rope_theta)
    v_ref = (xn_full @ wv2).reshape(x.shape[0], x.shape[1], 2, dh)
    o_ref = attention_core(q_ref, k_ref, v_ref, q_positions=pos_full,
                           kv_positions=pos_full, causal=True)
    gqa_ref = o_ref.reshape(x.shape[0], x.shape[1], H * dh) @ wo4
    for mode in ("barrier", "cais"):
        tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
        got = tp_mod.sp_attention(tpc4, x, ns, wq4, wk2, wv2, wo4, cfg_gqa)
        check(f"sp_attention.gqa_replicated_kv.{mode}",
              float(jnp.abs(got - gqa_ref).max()), 1e-5)

    # ---------------- whole-block graph vs PR-1 per-sub-layer path --------
    # sp_block builds ONE dataflow graph per transformer block (pass 2 fuses
    # the attention-out → FFN-in seam into fused_rs_ln_ag_multi); pin it to
    # the split sp_attention + sp_ffn / sp_moe_ffn composition on the 4-way
    # ring for dense, GQA, and MoE blocks, per backend, at 1e-6.
    import dataclasses as _dc

    import repro.models.transformer as tr_mod

    def split_block(tpc, x, params, cfg):
        p, mm = params, params["mixer"]
        r1 = x + tp_mod.sp_attention(
            tpc, x, p["norm1"]["scale"], mm["wq"], mm["wk"], mm["wv"],
            mm["wo"], cfg)
        if cfg.moe is not None:
            out, aux_ = tp_mod.sp_moe_ffn(tpc, r1, p["norm2"]["scale"],
                                          p["ffn"], cfg)
            return r1 + out, aux_
        f_ = p["ffn"]
        return r1 + tp_mod.sp_ffn(tpc, r1, p["norm2"]["scale"], f_["w_up"],
                                  f_.get("w_gate"), f_["w_down"],
                                  cfg.act), jnp.float32(0.0)

    cfg_blk = cfg_at                                  # dense, kv sharded
    cfg_blk_gqa = cfg_at.scaled(num_kv_heads=2)       # replicated KV
    cfg_blk_moe = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=d_ff, window=16)
    cfg_blk_moe = cfg_blk_moe.scaled(moe=_dc.replace(
        cfg_blk_moe.moe, capacity_factor=8.0))
    assert cfg_blk_moe.moe.num_experts % 4 == 0
    for label, cfg_b in (("dense", cfg_blk), ("gqa", cfg_blk_gqa),
                         ("moe", cfg_blk_moe)):
        params_b = tr_mod.init_block(jax.random.key(23), "attn", cfg_b,
                                     jnp.float32)
        for mode in ("barrier", "cais"):
            tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
            got, aux_g = tp_mod.sp_block(tpc4, x, params_b, cfg_b, "attn")
            ref, aux_r = split_block(tpc4, x, params_b, cfg_b)
            check(f"block_graph.{label}.{mode}",
                  float(jnp.abs(got - ref).max()), 1e-6)
            check(f"block_graph.{label}.{mode}.aux",
                  abs(float(aux_g) - float(aux_r)), 1e-6)
        # the block graph must actually carry the cross-sub-layer fusion
        if cfg_b.moe is None:
            core = tp_mod._attention_core_fn(cfg_b, 4)
            opt = df.optimize(tp_mod.dense_block_graph(
                core, True, cfg_b.act))
            ops = [n.op for n in opt.nodes]
            check(f"block_graph.{label}.pass2_fired",
                  0.0 if "fused_rs_ln_ag_multi" in ops else 1.0)

    # E < tp owner mapping (replicated expert weights, zero-capacity
    # padding): the shared routing closures must agree with a 1-device run
    # of the same params (capacity large enough that no token drops)
    params_ep = tr_mod.init_block(jax.random.key(24), "attn", cfg_blk_moe,
                                  jnp.float32)
    mesh8x = sharding.make_mesh((1, 8), ("data", "model"))   # tp=8 > E=4
    mesh1x = sharding.make_mesh((1, 1), ("data", "model"))
    outs_ep = {}
    for name_, mesh_ in (("tp8", mesh8x), ("tp1", mesh1x)):
        tpc_ = tp_mod.TPContext(mesh=mesh_, backend="cais", cais=cais4)
        outs_ep[name_], _ = tp_mod.sp_moe_ffn(
            tpc_, x, params_ep["norm2"]["scale"], params_ep["ffn"],
            cfg_blk_moe)
    check("sp_moe_ffn.e_lt_tp",
          float(np.abs(np.asarray(outs_ep["tp8"])
                       - np.asarray(outs_ep["tp1"])).max()), 1e-5)

    # ---------------- period-level graph vs per-block composition ---------
    # sp_period concatenates ≥2 block fragments into ONE graph / ONE
    # shard_map (pass 2 fuses the block→block rs→residual→ln→ag seam); pin
    # it to the per-block sp_block composition at 1e-6 on the 4-way ring for
    # dense, GQA, MoE, and a mixed attn/swa pattern, per backend.
    cfg_mixed = cfg_blk.scaled(window=16, layer_pattern=("attn", "swa"))
    for label, cfg_p, kinds_p in (
            ("dense", cfg_blk, ("attn", "attn")),
            ("gqa", cfg_blk_gqa, ("attn", "attn")),
            ("moe", cfg_blk_moe, ("attn", "attn")),
            ("mixed", cfg_mixed, ("attn", "swa"))):
        ps = [tr_mod.init_block(jax.random.key(30 + j), k_, cfg_p,
                                jnp.float32)
              for j, k_ in enumerate(kinds_p)]
        for mode in ("barrier", "cais"):
            tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
            got, aux_g = tp_mod.sp_period(tpc4, x, ps, cfg_p, kinds_p)
            refx, refaux = x, jnp.float32(0.0)
            for p_, k_ in zip(ps, kinds_p):
                refx, a_ = tp_mod.sp_block(tpc4, refx, p_, cfg_p, k_)
                refaux = refaux + a_
            check(f"period_graph.{label}.{mode}",
                  float(jnp.abs(got - refx).max()), 1e-6)
            check(f"period_graph.{label}.{mode}.aux",
                  abs(float(aux_g) - float(refaux)), 1e-6)

    # ---------------- microbatch-split period vs unsplit ------------------
    # sp_period(num_microbatches=2) splits the batch into two independent
    # chains merged into ONE graph (shared weights) re-concatenated inside
    # the same shard_map — the structure pass 3 turns into overlap_asym.
    # Acceptance (ISSUE 5): ≤1e-6 output parity vs the unsplit period on
    # the 4-way ring for dense/GQA/MoE, per backend. Dense/GQA aux is
    # trivially zero and checked; MoE aux is a load-balance statistic that
    # is not linear over sub-batches (mean of per-chain means ≠ full-batch
    # mean), so only the outputs are pinned there.
    x4 = jax.random.normal(jax.random.key(40), (4, 64, d), jnp.float32)
    for label, cfg_p in (("dense", cfg_blk), ("gqa", cfg_blk_gqa),
                         ("moe", cfg_blk_moe)):
        kinds_p = ("attn", "attn")
        ps_mb = [tr_mod.init_block(jax.random.key(50 + j), k_, cfg_p,
                                   jnp.float32)
                 for j, k_ in enumerate(kinds_p)]
        for mode in ("barrier", "cais"):
            tpc4 = tp_mod.TPContext(mesh=mesh4, backend=mode, cais=cais4)
            got1, aux1 = tp_mod.sp_period(tpc4, x4, ps_mb, cfg_p, kinds_p,
                                          num_microbatches=1)
            got2, aux2 = tp_mod.sp_period(tpc4, x4, ps_mb, cfg_p, kinds_p,
                                          num_microbatches=2)
            check(f"period_split.{label}.{mode}",
                  float(jnp.abs(got2 - got1).max()), 1e-6)
            if cfg_p.moe is None:
                check(f"period_split.{label}.{mode}.aux",
                      abs(float(aux2) - float(aux1)), 1e-6)
        # the "auto" heuristic resolves (to 1 at these smoke payloads) and
        # stays correct end to end
        tpc4a = tp_mod.TPContext(mesh=mesh4, backend="cais", cais=cais4,
                                 num_microbatches="auto")
        gota, _ = tp_mod.sp_period(tpc4a, x4, ps_mb, cfg_p, kinds_p)
        check(f"period_split.{label}.auto",
              float(jnp.abs(gota - got1).max()), 1e-6)
    # the model path reaches the split via the Runtime knob
    rt_mb = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                    tp=TPConfig(mode="cais", chunks=2, microbatches=2))
    rt_u = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                   tp=TPConfig(mode="cais", chunks=2))
    ps_rt = [tr_mod.init_block(jax.random.key(55 + j), "attn", cfg_blk,
                               jnp.float32) for j in range(2)]
    outs_rt = {}
    for name_, rt_ in (("split", rt_mb), ("unsplit", rt_u)):
        with sharding.use_mesh(mesh4):
            outs_rt[name_], _ = tr_mod._blocks_forward(
                ("attn", "attn"), ps_rt, x4, cfg_blk, rt_)
    check("period_split.runtime_knob",
          float(jnp.abs(outs_rt["split"] - outs_rt["unsplit"]).max()), 1e-6)

    # ---------------- perfsim planner vs greedy (repro.plan) --------------
    # tp_planner="perfsim" routes pass 3 + the microbatch choice through the
    # simulated-makespan search (plan cache pointed at a tempdir here); the
    # schedule may differ but the math may not: ≤1e-6 parity vs the greedy
    # planner on the 4-way ring, 2-block split period, per backend
    # (ISSUE 6 acceptance).
    import tempfile as _tf

    import repro.plan as plan_mod
    from repro.plan import cache as plan_cache
    _saved_cache = plan_cache._DEFAULT
    plan_cache._DEFAULT = plan_mod.PlanCache(root=_tf.mkdtemp())
    try:
        ps_pl = [tr_mod.init_block(jax.random.key(60 + j), "attn", cfg_blk,
                                   jnp.float32) for j in range(2)]
        for mode in ("barrier", "cais"):
            outs_pl = {}
            for planner in ("greedy", "perfsim"):
                tpc4p = tp_mod.TPContext(mesh=mesh4, backend=mode,
                                         cais=cais4, planner=planner)
                outs_pl[planner], _ = tp_mod.sp_period(
                    tpc4p, x4, ps_pl, cfg_blk, ("attn", "attn"),
                    num_microbatches=2)
            check(f"planner.perfsim_vs_greedy.{mode}",
                  float(jnp.abs(outs_pl["perfsim"]
                                - outs_pl["greedy"]).max()), 1e-6)
        st_pl = plan_cache._DEFAULT.stats
        check("planner.cache_observable",
              0.0 if st_pl["misses"] >= 1 else 1.0)
    finally:
        plan_cache._DEFAULT = _saved_cache

    # ---------------- decode-path TP (S=1: no sequence sharding) ----------
    # S=1 can't shard the sequence over the ring, but row/col-sharded GEMMs
    # don't need it: block_forward must route dense blocks through the
    # allreduce schedule (backend gemm_ar) instead of silently unsharding.
    from repro.core.backends import (CAISBackend, register_backend,
                                     unregister_backend)

    ar_calls = {"n": 0}

    class CountingCAIS(CAISBackend):
        name = "cais-count"

        def gemm_ar(self, xl, wl, axis, cc):
            ar_calls["n"] += 1
            return super().gemm_ar(xl, wl, axis, cc)

    register_backend(CountingCAIS())
    try:
        params_dec = tr_mod.init_block(jax.random.key(25), "attn", cfg_blk,
                                       jnp.float32)
        x1 = x[:, :1]                                   # (B, 1, d)
        outs_dec = {}
        for mode in ("cais-count", "auto"):
            rt_dec = Runtime(compute_dtype="float32", remat=False,
                             loss_chunk=16, tp=TPConfig(mode=mode, chunks=2))
            with sharding.use_mesh(mesh4):
                outs_dec[mode], _ = tr_mod.block_forward(
                    "attn", params_dec, x1, cfg_blk, rt_dec)
        check("decode.s1_block_parity",
              float(jnp.abs(outs_dec["cais-count"]
                            - outs_dec["auto"]).max()), 1e-4)
        # two sub-layers → two backend-dispatched allreduces traced
        check("decode.s1_backend_dispatch",
              0.0 if ar_calls["n"] >= 2 else 1.0)
    finally:
        unregister_backend("cais-count")

    # ragged S (S % tp != 0, S > 1): dense blocks keep TP via the allreduce
    # schedule, the MoE fallback must not die on an unsatisfiable
    # sequence-parallel / group sharding constraint
    x3 = x[:, :3]
    outs_rag = {}
    for mode in ("cais", "auto"):
        rt_rag = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                         tp=TPConfig(mode=mode, chunks=2))
        with sharding.use_mesh(mesh4):
            outs_rag[mode], _ = tr_mod.block_forward(
                "attn", params_dec, x3, cfg_blk, rt_rag)
    check("decode.ragged_s_parity",
          float(jnp.abs(outs_rag["cais"] - outs_rag["auto"]).max()), 1e-4)
    rt_rag = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                     tp=TPConfig(mode="cais", chunks=2))
    params_rag_moe = tr_mod.init_block(jax.random.key(26), "attn",
                                       cfg_blk_moe, jnp.float32)
    with sharding.use_mesh(mesh4):
        out_rm, _ = tr_mod.block_forward("attn", params_rag_moe, x3,
                                         cfg_blk_moe, rt_rag)
    check("decode.ragged_s_moe_runs",
          0.0 if out_rm.shape == x3.shape else 1.0)

    # ---------------- serving: paged KV through the serve-period graph ----
    # S=1 decode rows and chunked-prefill rows with S % tp != 0 must BOTH
    # keep TP via backend-dispatched gemm_ar (never silently unshard), and a
    # mixed prefill+decode batch must match the same rows run in
    # single-mode batches.
    from repro.models.attention import KVView

    mesh14 = sharding.make_mesh((1, 4), ("data", "model"))
    cfg_srv = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128)
    params_srv = None

    def serve_views():
        bt = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
        pad = -jnp.ones((1, 5), jnp.int32)
        v_pre_a = KVView(bt, jnp.concatenate(
            [jnp.arange(5, dtype=jnp.int32)[None, :], pad]),
            jnp.asarray([5, 0], jnp.int32), jnp.asarray([4, 0], jnp.int32))
        v_dec = KVView(bt, jnp.asarray([[5], [-1]], jnp.int32),
                       jnp.asarray([6, 0], jnp.int32),
                       jnp.asarray([0, 0], jnp.int32))
        v_pre_b = KVView(bt, jnp.asarray([[-1] * 3, [0, 1, 2]], jnp.int32),
                         jnp.asarray([0, 3], jnp.int32),
                         jnp.asarray([0, 2], jnp.int32))
        v_mix = KVView(bt, jnp.asarray([[5, -1, -1], [0, 1, 2]], jnp.int32),
                       jnp.asarray([6, 3], jnp.int32),
                       jnp.asarray([0, 2], jnp.int32))
        return v_pre_a, v_dec, v_pre_b, v_mix

    t_pre_a = jnp.asarray([[1, 2, 3, 4, 5], [0] * 5], jnp.int32)
    t_dec = jnp.asarray([[7], [0]], jnp.int32)
    t_pre_b = jnp.asarray([[0] * 3, [9, 8, 7]], jnp.int32)
    t_mix = jnp.asarray([[7, 0, 0], [9, 8, 7]], jnp.int32)

    def serve_logits(mode):
        nonlocal params_srv
        rt_s = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                       tp=TPConfig(mode=mode, chunks=2))
        m = build_model(cfg_srv, rt_s)
        if params_srv is None:
            params_srv = m.init(jax.random.key(31))
        v_pre_a, v_dec, v_pre_b, v_mix = serve_views()
        with sharding.use_mesh(mesh14):
            pools = m.init_pools(8, 4)
            _, pools = m.serve_step(params_srv, t_pre_a, pools, v_pre_a)
            lg_dec, _ = m.serve_step(params_srv, t_dec, pools, v_dec)
            lg_pre, _ = m.serve_step(params_srv, t_pre_b, pools, v_pre_b)
            lg_mix, _ = m.serve_step(params_srv, t_mix, pools, v_mix)
        return lg_dec, lg_pre, lg_mix

    for mode in ("barrier", "cais"):
        lg_dec, lg_pre, lg_mix = serve_logits(mode)
        err = max(float(jnp.abs(lg_mix[0] - lg_dec[0]).max()),
                  float(jnp.abs(lg_mix[1] - lg_pre[1]).max()))
        check(f"serve.mixed_vs_single.{mode}", err, 1e-6)

    ar_calls["n"] = 0
    register_backend(CountingCAIS())
    try:
        serve_logits("cais-count")
        # stack_step scans over periods, so the period graph traces ONCE
        # per serve_step shape: 2 gemm_ar dispatches (attention out-proj +
        # FFN down-proj) each for the S=5 prefill, the S=1 decode and the
        # S=3 chunk/mixed steps (the two S=3 steps may share a trace)
        check("serve.backend_dispatch_gemm_ar",
              0.0 if ar_calls["n"] >= 6 else 1.0)
    finally:
        unregister_backend("cais-count")

    # ---------------- full model: auto == barrier == cais ----------------
    mesh2 = sharding.make_mesh((2, 4), ("data", "model"))
    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128)
    tokens = jax.random.randint(jax.random.key(7), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    key = jax.random.key(0)
    losses = {}
    for mode in ("auto", "barrier", "cais"):
        rt = Runtime(compute_dtype="float32", remat=(mode == "cais"),
                     loss_chunk=16, tp=TPConfig(mode=mode, chunks=2))
        model = build_model(cfg, rt)
        params = model.init(key)
        with sharding.use_mesh(mesh2):
            losses[mode] = float(jax.jit(model.loss)(params, batch))
    check("model.auto_vs_barrier", abs(losses["auto"] - losses["barrier"]))
    check("model.auto_vs_cais", abs(losses["auto"] - losses["cais"]))

    # cais grads finite under remat
    rt = Runtime(compute_dtype="float32", remat=True, loss_chunk=16,
                 tp=TPConfig(mode="cais", chunks=2))
    model = build_model(cfg, rt)
    params = model.init(key)
    with sharding.use_mesh(mesh2):
        loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    ok = all(np.all(np.isfinite(np.asarray(g, np.float32)))
             for g in jax.tree.leaves(grads))
    check("model.cais_grads_finite", 0.0 if ok else 1.0)

    # HLO structure: cais mode must contain collective-permutes and no
    # all-gather on the FFN path; barrier mode must contain all-gathers.
    def hlo_for(mode):
        rt = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                     tp=TPConfig(mode=mode, chunks=2))
        model = build_model(cfg, rt)
        params = model.init(key)
        with sharding.use_mesh(mesh2):
            return jax.jit(model.loss).lower(params, batch).compile().as_text()

    cais_hlo = hlo_for("cais")
    barrier_hlo = hlo_for("barrier")
    check("hlo.cais_has_permute",
          0.0 if "collective-permute" in cais_hlo else 1.0)
    check("hlo.barrier_has_allgather",
          0.0 if "all-gather" in barrier_hlo else 1.0)

    # ---------------- CAIS expert all-to-all (EP) --------------------------
    n, C, d, F = 8, 16, 32, 48
    send8 = jax.random.normal(jax.random.key(9), (8, n, C, d))
    wu8 = jax.random.normal(jax.random.key(10), (8, d, F)) * 0.1
    wd8 = jax.random.normal(jax.random.key(12), (8, F, d)) * 0.1

    def a2a(kind, bidir=True):
        def local(send, wu, wd):
            s, u, w = send[0], wu[0], wd[0]
            ffn = lambda t: jax.nn.gelu(t @ u) @ w
            if kind == "barrier":
                return prim.barrier_a2a_expert_ffn(s, ffn, "model")[None]
            return prim.a2a_expert_ffn(
                s, ffn, "model", CAISConfig(bidirectional=bidir))[None]
        return jax.jit(sharding.shard_map(
            local, mesh=mesh, in_specs=(P("model"), P("model"), P("model")),
            out_specs=P("model"), check_vma=False))(send8, wu8, wd8)

    ref_a2a = a2a("barrier")
    check("a2a_expert.cais", float(jnp.abs(a2a("cais") - ref_a2a).max()),
          1e-5)
    check("a2a_expert.cais_uni",
          float(jnp.abs(a2a("cais", bidir=False) - ref_a2a).max()), 1e-5)

    # MoE model: CE identical across modes (aux estimator partitioning
    # differs by design — isolate it)
    import dataclasses

    import repro.models.transformer as tr
    aux_w = tr.AUX_LOSS_WEIGHT
    tr.AUX_LOSS_WEIGHT = 0.0
    try:
        cfg_moe = get_arch("mixtral-8x7b").smoke().scaled(
            num_layers=2, d_model=64, num_heads=8, num_kv_heads=8,
            head_dim=16, d_ff=64, window=16)
        cfg_moe = cfg_moe.scaled(moe=dataclasses.replace(
            cfg_moe.moe, capacity_factor=8.0, group_size=1024))
        toks = jax.random.randint(jax.random.key(13), (2, 32), 0,
                                  cfg_moe.vocab_size)
        bmoe = {"tokens": toks, "labels": toks}
        ls = {}
        for mode in ("auto", "cais"):
            rt = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                         tp=TPConfig(mode=mode, chunks=2))
            mm = build_model(cfg_moe, rt)
            pp = mm.init(jax.random.key(0))
            with sharding.use_mesh(mesh2):
                ls[mode] = float(jax.jit(mm.loss)(pp, bmoe))
        check("moe.auto_vs_cais_ce", abs(ls["auto"] - ls["cais"]), 2e-5)
    finally:
        tr.AUX_LOSS_WEIGHT = aux_w

    # ---------------- graph-built backward: train grads vs autodiff -------
    # Train-loss gradients routed through sp_period's graph-built custom VJP
    # (the backward is itself a dataflow graph; fwd+bwd merge for pass 3,
    # docs/training.md) must match plain JAX autodiff of the UNSPLIT forward
    # at 1e-6 on the 4-way ring, per backend, for dense / GQA / MoE — and
    # compose with remat (jax.checkpoint replays the period forward, then
    # re-enters the same graph VJP).
    cfg_gqa2 = cfg.scaled(num_kv_heads=2)

    def train_grads(cfg_, batch_, rt_):
        model_ = build_model(cfg_, rt_)
        params_ = model_.init(jax.random.key(0))
        with sharding.use_mesh(mesh2):
            _, grads_ = jax.jit(jax.value_and_grad(model_.loss))(
                params_, batch_)
        return grads_

    def max_leaf_err(a, b):
        errs = jax.tree.map(
            lambda u, v: float(jnp.abs(u.astype(jnp.float32)
                                       - v.astype(jnp.float32)).max()), a, b)
        return max(jax.tree.leaves(errs))

    for label, cfg_g, batch_g, mb_g in (
            ("dense", cfg, batch, 2), ("gqa", cfg_gqa2, batch, 2),
            # MoE now rides the graph backward THROUGH the IR (route /
            # a2a_ffn / unroute adjoints with the aux cotangent seeded per
            # chain) — unsplit (mb=1) only because an explicit microbatch
            # split changes the aux statistic itself
            ("moe", cfg_moe, bmoe, 1)):
        for mode in ("barrier", "cais"):
            rt_graph = Runtime(
                compute_dtype="float32", remat=False, loss_chunk=16,
                tp=TPConfig(mode=mode, chunks=2, microbatches=mb_g,
                            graph_backward=True))
            rt_auto = Runtime(
                compute_dtype="float32", remat=False, loss_chunk=16,
                tp=TPConfig(mode=mode, chunks=2, graph_backward=False))
            err = max_leaf_err(train_grads(cfg_g, batch_g, rt_graph),
                               train_grads(cfg_g, batch_g, rt_auto))
            check(f"train_grad.graph_vs_autodiff.{label}.{mode}", err, 1e-6)
    for mode in ("barrier", "cais"):
        rt_graph = Runtime(
            compute_dtype="float32", remat=True, loss_chunk=16,
            tp=TPConfig(mode=mode, chunks=2, microbatches=2,
                        graph_backward=True))
        rt_auto = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                          tp=TPConfig(mode=mode, chunks=2,
                                      graph_backward=False))
        err = max_leaf_err(train_grads(cfg, batch, rt_graph),
                           train_grads(cfg, batch, rt_auto))
        check(f"train_grad.graph_vs_autodiff.remat.{mode}", err, 1e-6)

    # decode/ragged train grads: the replicated-activation layout
    # (seq_sharded=False — S=1 decode and ragged S % tp != 0 shapes) now
    # has graph-path adjoints (gemm_col ⇒ grad allreduce through w^T,
    # gemm_ar ⇒ local dx/dw), so sp_block's graph-built custom VJP must
    # match the graph_backward=False autodiff of the SAME forward at 1e-6
    # per backend — graph_backward no longer silently excludes
    # decode-shaped periods.
    params_dgr = tr_mod.init_block(jax.random.key(27), "attn", cfg_blk,
                                   jnp.float32)
    x_full = jax.random.normal(jax.random.key(28), (2, 8, d), jnp.float32)
    for s_lab, s_len in (("s1", 1), ("ragged_s3", 3)):
        xs = x_full[:, :s_len]
        for mode in ("barrier", "cais"):
            def dec_grads(graph_bwd):
                tpc_d = tp_mod.TPContext(mesh=mesh4, backend=mode,
                                         cais=cais4,
                                         graph_backward=graph_bwd)

                def f(x_, p_):
                    out, _ = tp_mod.sp_block(tpc_d, x_, p_, cfg_blk, "attn",
                                             seq_sharded=False)
                    # mean, not sum: keep the cotangent O(1) so the 1e-6
                    # absolute pin measures schedule parity, not loss scale
                    return jnp.mean(out * out)

                return jax.jit(jax.grad(f, argnums=(0, 1)))(xs, params_dgr)

            check(f"train_grad.decode_gemm_ar.{s_lab}.{mode}",
                  max_leaf_err(dec_grads(True), dec_grads(False)), 1e-6)

    # dispatch-counter proof: the decode-layout backward allreduces run
    # through the backend (each gemm_col adjoint dispatches one backend
    # gemm_ar over the transposed weight), never through implicit psums.
    ar_bwd = {"n": 0}

    class CountingARCAIS(CAISBackend):
        name = "cais-count-ar"

        def gemm_ar(self, xl, wl, axis, cc):
            ar_bwd["n"] += 1
            return super().gemm_ar(xl, wl, axis, cc)

    register_backend(CountingARCAIS())
    try:
        tpc_cnt = tp_mod.TPContext(mesh=mesh4, backend="cais-count-ar",
                                   cais=cais4, graph_backward=True)

        def f_cnt(x_, p_):
            out, _ = tp_mod.sp_block(tpc_cnt, x_, p_, cfg_blk, "attn",
                                     seq_sharded=False)
            return jnp.sum(out * out)

        jax.grad(f_cnt)(x_full[:, :1], params_dgr)
        n_total = ar_bwd["n"]
        ar_bwd["n"] = 0
        tp_mod.sp_block(tpc_cnt, x_full[:, :1], params_dgr, cfg_blk, "attn",
                        seq_sharded=False)
        n_fwd = ar_bwd["n"]
    finally:
        unregister_backend("cais-count-ar")
    # backward trace = forward replay + ≥1 grad-allreduce per projection
    check("train_grad.decode_gemm_ar.backend_dispatch",
          0.0 if n_total > n_fwd >= 2 else 1.0)

    # ---------------- hierarchical 2D-mesh TP: flat ≡ tp_in × tp_out ------
    # Full-model loss + train grads on a tp_in=2 × tp_out=4 mesh (per-axis
    # collective composition, docs/topology.md) must match the flat 8-ring
    # at 1e-6 per backend. The MoE config carries E=8 experts so BOTH
    # meshes take the period-graph path: flat shards experts over the whole
    # ring (E % 8 == 0), the 2D mesh takes grouped EP — experts over the
    # slow tp_out axis only, replicated across tp_in.
    mesh_flat8 = sharding.make_mesh((1, 8), ("data", "model"))
    mesh_2d = sharding.make_tp_mesh(2, 4)
    cfg_moe8 = cfg_moe.scaled(moe=dataclasses.replace(
        cfg_moe.moe, num_experts=8))

    def loss_and_grads(cfg_, batch_, rt_, mesh_):
        model_ = build_model(cfg_, rt_)
        params_ = model_.init(jax.random.key(0))
        with sharding.use_mesh(mesh_):
            l_, g_ = jax.jit(jax.value_and_grad(model_.loss))(
                params_, batch_)
        return float(l_), g_

    for label, cfg_t, batch_t in (("dense", cfg, batch),
                                  ("gqa", cfg_gqa2, batch),
                                  ("moe", cfg_moe8, bmoe)):
        for mode in ("barrier", "cais"):
            rt_t = Runtime(compute_dtype="float32", remat=False,
                           loss_chunk=16,
                           tp=TPConfig(mode=mode, chunks=2,
                                       graph_backward=True))
            l_flat, g_flat = loss_and_grads(cfg_t, batch_t, rt_t, mesh_flat8)
            l_2d, g_2d = loss_and_grads(cfg_t, batch_t, rt_t, mesh_2d)
            check(f"topo2d.{label}.{mode}", abs(l_flat - l_2d), 1e-6)
            check(f"topo2d.{label}.{mode}.train_grad",
                  max_leaf_err(g_flat, g_2d), 1e-6)

    # grouped-EP dispatch proof: on the 2D mesh the expert all-to-all must
    # only ever cross the slow tp_out axis — forward AND backward: the
    # hierarchical backend re-enters a2a_expert_ffn / grad_a2a_expert_ffn
    # with the concrete leg axis, so every non-composite axis the backend
    # sees must be tp_out (grouped-EP grads stay off the fast tp_in links).
    a2a_axes = []
    grad_a2a_axes = []

    class RecordingCAIS(CAISBackend):
        name = "cais-record"

        def a2a_expert_ffn(self, send, ffn, axis, cais):
            a2a_axes.append(axis)
            return super().a2a_expert_ffn(send, ffn, axis, cais)

        def grad_a2a_expert_ffn(self, send, gy, bwd_row, axis, cais):
            grad_a2a_axes.append(axis)
            return super().grad_a2a_expert_ffn(send, gy, bwd_row, axis,
                                               cais)

    register_backend(RecordingCAIS())
    try:
        rt_rec = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                         tp=TPConfig(mode="cais-record", chunks=2,
                                     graph_backward=True))
        model_rec = build_model(cfg_moe8, rt_rec)
        params_rec = model_rec.init(jax.random.key(0))
        with sharding.use_mesh(mesh_2d):
            l_rec, g_rec = jax.jit(jax.value_and_grad(model_rec.loss))(
                params_rec, bmoe)
            l_rec = float(l_rec)
    finally:
        unregister_backend("cais-record")
    rt_ref = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                     tp=TPConfig(mode="cais", chunks=2))
    model_ref = build_model(cfg_moe8, rt_ref)
    params_ref = model_ref.init(jax.random.key(0))
    with sharding.use_mesh(mesh_2d):
        l_ref, g_ref = jax.jit(jax.value_and_grad(model_ref.loss))(
            params_ref, bmoe)
        l_ref = float(l_ref)
    concrete = [a for a in a2a_axes if not isinstance(a, tuple)]
    concrete_g = [a for a in grad_a2a_axes if not isinstance(a, tuple)]
    check("grouped_ep.dispatch.parity", abs(l_rec - l_ref), 1e-6)
    check("grouped_ep.dispatch.parity.train_grad",
          max_leaf_err(g_rec, g_ref), 1e-6)
    check("grouped_ep.dispatch.tp_out_only",
          0.0 if (concrete
                  and all(a == sharding.TP_OUT_AXIS for a in concrete))
          else 1.0)
    # the backward a2a runs through the backend (dispatch-counter proof)
    # and its concrete legs stay off tp_in under grouped EP too
    check("grouped_ep.grad_dispatch.through_backend",
          0.0 if len(grad_a2a_axes) >= 1 else 1.0)
    check("grouped_ep.grad_dispatch.tp_out_only",
          0.0 if (concrete_g
                  and all(a == sharding.TP_OUT_AXIS for a in concrete_g))
          else 1.0)

    # ---------------- elastic resharding across meshes --------------------
    # Train 2 steps on a (2,4) mesh, checkpoint, restore onto (4,2) and
    # continue — losses must continue exactly (deliverable: elastic scaling).
    import tempfile

    from repro.checkpoint import store as ckpt_store
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import make_batch
    from repro.launch import specs as SP
    from repro.optim import constant_schedule, make_optimizer
    from repro.train.step import init_state, make_train_step

    cfg_e = get_arch("internlm2-1.8b").smoke()
    rt_e = Runtime(compute_dtype="float32", remat=False, loss_chunk=16)
    model_e = build_model(cfg_e, rt_e)
    opt_e = make_optimizer("adamw", constant_schedule(1e-3))
    step_e = jax.jit(make_train_step(model_e, opt_e, rt_e))
    shp = ShapeConfig("t", 16, 4, "train")

    def run_steps(state, mesh_, a, b):
        with sharding.use_mesh(mesh_):
            for s in range(a, b):
                state, met = step_e(state, make_batch(cfg_e, shp, s))
        return state, float(met["loss"])

    mesh_a = sharding.make_mesh((2, 4), ("data", "model"))
    mesh_b = sharding.make_mesh((4, 2), ("data", "model"))

    st = init_state(model_e, opt_e, jax.random.key(0))
    st_ref = jax.tree.map(jnp.copy, st)
    # reference: 4 steps without interruption (no mesh)
    st_ref, loss_ref = run_steps(st_ref, None, 0, 4)

    st, _ = run_steps(st, mesh_a, 0, 2)
    with tempfile.TemporaryDirectory() as td:
        ckpt_store.save(td, st, step=2)
        template = jax.eval_shape(lambda: st)
        shapes = jax.eval_shape(lambda: st)
        sh_b = SP.state_shardings(cfg_e, mesh_b, shapes, rt_e)
        restored, _ = ckpt_store.restore(td, template)
        restored = jax.device_put(restored, sh_b)
    st2, loss2 = run_steps(restored, mesh_b, 2, 4)
    check("elastic.loss_continuity", abs(loss2 - loss_ref), 1e-4)

    # ---------------- int8 gradient compression (error feedback) ----------
    from repro.optim.compression import compressed_psum, init_error_feedback

    mesh_dp = sharding.make_mesh((8,), ("data",))
    gkey = jax.random.key(11)
    local_grads = jax.random.normal(gkey, (8, 64)) * jnp.linspace(
        0.1, 3.0, 8)[:, None]   # heterogeneous per-device grads
    exact_mean = jnp.mean(local_grads, axis=0)

    def dp_reduce(g, ef):
        return compressed_psum({"g": g}, {"g": ef}, axes=("data",))

    ef0 = jnp.zeros((1, 64))

    red, ef = jax.jit(sharding.shard_map(
        dp_reduce, mesh=mesh_dp,
        in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False))(local_grads, jnp.zeros_like(local_grads))
    # every replica holds the same reduced value, ≈ exact mean within int8
    approx = red["g"][0]
    rel = float(jnp.abs(approx - exact_mean).max()
                / (jnp.abs(exact_mean).max() + 1e-9))
    check("compression.int8_close", rel, 0.05)

    # error feedback: repeated reduction of a CONSTANT gradient with EF must
    # average to the exact value (bias decays)
    acc = jnp.zeros((64,))
    ef_state = jnp.zeros_like(local_grads)
    for _ in range(16):
        red, new_ef = jax.jit(sharding.shard_map(
            dp_reduce, mesh=mesh_dp,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)),
            check_vma=False))(local_grads, ef_state)
        ef_state = new_ef["g"]
        acc = acc + red["g"][0]
    rel_ef = float(jnp.abs(acc / 16 - exact_mean).max()
                   / (jnp.abs(exact_mean).max() + 1e-9))
    check("compression.error_feedback_unbiased", rel_ef, 0.01)

    if FAILED:
        print("FAILED:", FAILED)
        sys.exit(1)
    print("ALL OK")


if __name__ == "__main__":
    main()
