"""CollectiveBackend registry + IR-driven sub-layer tests (single device;
the multi-device parity checks live in multidev_checks.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.core import backends as be
from repro.core import dataflow as df
from repro.core import tp
from repro.core.primitives import CAISConfig
from repro.models.layers import activation, apply_norm
from repro.runtime import Runtime, TPConfig

# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtin_backends_registered():
    assert {"auto", "barrier", "cais"} <= set(be.available_backends())
    assert be.get_backend("cais").name == "cais"
    assert be.get_backend("barrier").explicit
    assert not be.get_backend("auto").explicit


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown collective backend"):
        be.get_backend("no-such-backend")


def test_get_backend_passes_instances_through():
    inst = be.get_backend("barrier")
    assert be.get_backend(inst) is inst


def test_registry_roundtrip():
    class MyBackend(be.BarrierBackend):
        name = "test-custom"

    inst = MyBackend()
    try:
        be.register_backend(inst)
        assert be.get_backend("test-custom") is inst
        assert "test-custom" in be.available_backends()
        # registered backends are full TPContext citizens
        mesh = sharding.make_mesh((1, 1), ("data", "model"))
        tpc = tp.TPContext(mesh=mesh, backend="test-custom")
        assert tpc.backend is inst
        assert tpc.mode == "test-custom"
    finally:
        be.unregister_backend("test-custom")
    with pytest.raises(ValueError):
        be.get_backend("test-custom")


def test_register_rejects_anonymous():
    with pytest.raises(ValueError):
        be.register_backend(be.CollectiveBackend())


def test_engine_rejects_unknown_tp_mode():
    from repro.serve.engine import Engine

    with pytest.raises(ValueError, match="unknown collective backend"):
        Engine(model=None, params=None, cfg=None,
               rt=Runtime(tp=TPConfig(mode="bogus")))


# ---------------------------------------------------------------------------
# compute-aware chunk planning (cais backend)
# ---------------------------------------------------------------------------


def test_cais_backend_plans_chunks():
    cais_be = be.get_backend("cais")
    # big payload on a big ring: planner picks finer chunking than tiny one
    big = cais_be.plan_chunks(512 * 1024 * 1024, ring=16)
    small = cais_be.plan_chunks(64 * 1024, ring=16)
    assert big >= small >= 1
    # staging budget respected: chunk bytes fit the default 4 MiB budget
    from repro.core import coordination
    p = coordination.plan(512 * 1024 * 1024, 16)
    assert p.staging_bytes <= 4 * 1024 ** 2


def test_cais_resolve_honors_static_override():
    cais_be = be.get_backend("cais")
    pinned = CAISConfig(num_chunks=3)
    assert cais_be._resolve(pinned, 1 << 30, 8) is pinned
    auto = CAISConfig()                     # num_chunks=None
    resolved = cais_be._resolve(auto, 1 << 30, 8)
    assert resolved.num_chunks is not None and resolved.num_chunks >= 1


# ---------------------------------------------------------------------------
# dataflow optimizer: shared-gather fusion + reaches
# ---------------------------------------------------------------------------


def test_ffn_graph_fuses_to_backend_ops():
    g = df.optimize(tp.ffn_sublayer_graph(True, "silu"))
    ops = [n.op for n in g.nodes if n.op != "input"]
    assert ops == ["layernorm", "ag_gemm_multi", "custom", "gemm_rs"]
    g2 = df.optimize(tp.ffn_sublayer_graph(False, "gelu"))
    ops2 = [n.op for n in g2.nodes if n.op != "input"]
    assert ops2 == ["layernorm", "ag_gemm", "custom", "gemm_rs"]


def test_attention_graph_shares_one_gather():
    g = df.optimize(tp.attention_sublayer_graph(lambda q, k, v: q))
    multi = [n for n in g.nodes if n.op == "ag_gemm_multi"]
    assert len(multi) == 1
    assert multi[0].weights == ("wq", "wk", "wv")
    assert multi[0].outputs == ("q", "k", "v")
    assert not any(n.op == "allgather" for n in g.nodes)


def test_shared_gather_not_fused_when_escaping():
    """A gather whose value is itself a graph output must stay unfused."""
    nodes = [
        df.Node("x", "input"),
        df.Node("agx", "allgather", ("x",)),
        df.Node("a", "gemm_col", ("agx",), ("wa",)),
        df.Node("b", "gemm_col", ("agx",), ("wb",)),
    ]
    g = df.optimize(df.Graph(nodes, outputs=("a", "b", "agx")))
    assert any(n.op == "allgather" for n in g.nodes)


def test_reaches_adjacency():
    g = df.sublayer_graph()
    assert g.reaches("x", "g2")
    assert g.reaches("g1", "ag")
    assert not g.reaches("g2", "x")
    assert not g.reaches("ln", "g1")


# ---------------------------------------------------------------------------
# graph-routed sub-layers: parity vs hand-fused math (tp=1 mesh)
# ---------------------------------------------------------------------------


def _ffn_ref(x, ns, wu, wg, wd, act):
    xn = apply_norm("rmsnorm", {"scale": ns}, x)
    if wg is not None:
        return (activation(act, xn @ wg) * (xn @ wu)) @ wd
    return activation(act, xn @ wu) @ wd


@pytest.mark.parametrize("backend", ["barrier", "cais"])
@pytest.mark.parametrize("gated", [True, False])
def test_sp_ffn_graph_parity_single_device(backend, gated):
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    B, S, d, F = 2, 8, 16, 32
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (B, S, d))
    ns = jax.random.normal(ks[1], (d,)) * 0.1 + 1.0
    wu = jax.random.normal(ks[2], (d, F)) * 0.1
    wg = jax.random.normal(ks[3], (d, F)) * 0.1 if gated else None
    wd = jax.random.normal(ks[4], (F, d)) * 0.1
    tpc = tp.TPContext(mesh=mesh, backend=backend)
    out = tp.sp_ffn(tpc, x, ns, wu, wg, wd, "silu")
    ref = _ffn_ref(x, ns, wu, wg, wd, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("backend", ["barrier", "cais"])
def test_sp_attention_graph_parity_single_device(backend):
    from repro.configs import get_arch

    cfg = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=64)
    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    B, S, d = 2, 8, 32
    ks = jax.random.split(jax.random.key(1), 6)
    x = jax.random.normal(ks[0], (B, S, d))
    ns = jnp.ones((d,))
    wq, wk, wv, wo = (jax.random.normal(k, (d, d)) * 0.1 for k in ks[1:5])
    outs = {}
    for name in ("barrier", backend):
        tpc = tp.TPContext(mesh=mesh, backend=name)
        outs[name] = tp.sp_attention(tpc, x, ns, wq, wk, wv, wo, cfg)
    np.testing.assert_allclose(np.asarray(outs[backend]),
                               np.asarray(outs["barrier"]), atol=1e-5)
