"""Docs sanity checker (CI: ``python -m tests.check_docs``).

Every fenced ``` code block in README.md and docs/*.md must be closed, and
every repo path the docs reference (backticked or markdown-linked) must
exist in the tree — so the docs cannot silently rot as files move.
``tests/test_docs.py`` wraps this for tier-1.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
# repo-relative paths as they appear in docs: `src/...`, (docs/backends.md), …
PATH_RE = re.compile(
    r"[`(]((?:src|docs|tests|benchmarks|examples|\.github)/[\w./-]+"
    r"|[A-Z][A-Z_a-z0-9]*\.md|pytest\.ini|requirements-dev\.txt)[`)]")


def check_file(md: pathlib.Path) -> list:
    text = md.read_text()
    errs = []
    if text.count("```") % 2:
        errs.append(f"{md.relative_to(ROOT)}: unbalanced ``` code fence")
    for ref in sorted({m.group(1) for m in PATH_RE.finditer(text)}):
        if not (ROOT / ref).exists():
            errs.append(f"{md.relative_to(ROOT)}: referenced path {ref!r} "
                        f"does not exist")
    return errs


def main() -> int:
    mds = [p for p in [ROOT / "README.md",
                       *sorted((ROOT / "docs").glob("*.md"))] if p.exists()]
    if not mds:
        print("check_docs: no README.md or docs/*.md found", file=sys.stderr)
        return 1
    errs = [e for md in mds for e in check_file(md)]
    for e in errs:
        print(f"DOCS {e}", file=sys.stderr)
    print(f"check_docs: {len(mds)} files, {len(errs)} problems")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
