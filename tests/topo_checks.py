"""Hierarchical 2D-mesh TP parity checks, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see
tests/test_topology.py — the main pytest process keeps a single device).

Property layer: the `_hypothesis_compat` strategies sweep mesh
factorizations (1x8, 2x4, 4x2, 8x1), sequence shapes and backend choices;
every 2D-mesh run must match the flat-ring run of the same computation.
Prints one `CHECK <name> <maxerr>` line per assertion; exits non-zero on
any failure.
"""
import sys

sys.path.insert(0, "tests")  # run as `python tests/topo_checks.py` from root

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from _hypothesis_compat import given, st
from repro import sharding
from repro.configs import get_arch
from repro.core import tp as tp_mod
from repro.core.backends import CAISBackend, get_backend, register_backend, \
    unregister_backend
from repro.core.primitives import CAISConfig
from repro.models import build_model
from repro.runtime import Runtime, TPConfig

FAILED = []

FACTORIZATIONS = ((1, 8), (2, 4), (4, 2), (8, 1))


def check(name, err, tol=1e-6):
    print(f"CHECK {name} {err:.3e}")
    if not (err <= tol):
        FAILED.append((name, err))


def _flat_mesh():
    return sharding.make_mesh((1, 8), ("data", "model"))


def main():
    assert len(jax.devices()) == 8, jax.devices()

    d, d_ff = 32, 48
    cais = CAISConfig(num_chunks=2)
    ks = jax.random.split(jax.random.key(0), 8)
    ns = jax.random.normal(ks[0], (d,)) * 0.1 + 1.0
    wu = jax.random.normal(ks[1], (d, d_ff)) * 0.1
    wg = jax.random.normal(ks[2], (d, d_ff)) * 0.1
    wd = jax.random.normal(ks[3], (d_ff, d)) * 0.1

    cfg_at = get_arch("deepseek-7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=8, num_kv_heads=8, head_dim=8,
        d_ff=d_ff)
    cfg_gqa = cfg_at.scaled(num_kv_heads=2)
    kat = jax.random.split(jax.random.key(1), 4)
    wq, wk, wv, wo = (jax.random.normal(k, (d, d)) * 0.1 for k in kat)
    kkv = jax.random.split(jax.random.key(2), 2)
    dh = cfg_at.resolved_head_dim
    wk2 = jax.random.normal(kkv[0], (d, 2 * dh)) * 0.1
    wv2 = jax.random.normal(kkv[1], (d, 2 * dh)) * 0.1

    # flat-ring references, one per (S, backend) — computed lazily
    refs = {}

    def flat_ref(kind, S, mode):
        key = (kind, S, mode)
        if key not in refs:
            x = jax.random.normal(jax.random.key(100 + S), (2, S, d),
                                  jnp.float32)
            tpc = tp_mod.TPContext(mesh=_flat_mesh(), backend=mode, cais=cais)
            if kind == "ffn":
                refs[key] = tp_mod.sp_ffn(tpc, x, ns, wu, wg, wd, "silu")
            elif kind == "attn":
                refs[key] = tp_mod.sp_attention(tpc, x, ns, wq, wk, wv, wo,
                                                cfg_at)
            else:  # gqa (replicated KV on the flat ring: 2 heads < 8)
                refs[key] = tp_mod.sp_attention(tpc, x, ns, wq, wk2, wv2, wo,
                                                cfg_gqa)
        return refs[key]

    # ---------------- property sweep: flat ring == 2D mesh ----------------
    @given(topo=st.sampled_from(FACTORIZATIONS),
           mode=st.sampled_from(["barrier", "cais"]),
           S=st.sampled_from([8, 24, 64]),
           kind=st.sampled_from(["ffn", "attn", "gqa"]))
    def sweep(topo, mode, S, kind):
        i, o = topo
        x = jax.random.normal(jax.random.key(100 + S), (2, S, d), jnp.float32)
        mesh2d = sharding.make_tp_mesh(i, o)
        tpc = tp_mod.TPContext(mesh=mesh2d, backend=mode, cais=cais)
        if kind == "ffn":
            got = tp_mod.sp_ffn(tpc, x, ns, wu, wg, wd, "silu")
        elif kind == "attn":
            got = tp_mod.sp_attention(tpc, x, ns, wq, wk, wv, wo, cfg_at)
        else:
            got = tp_mod.sp_attention(tpc, x, ns, wq, wk2, wv2, wo, cfg_gqa)
        err = float(jnp.abs(got - flat_ref(kind, S, mode)).max())
        check(f"sweep.{kind}.{mode}.t{i}x{o}.S{S}", err)

    sweep()

    # ---------------- ragged / decode shapes: hier gemm_ar ----------------
    # S % tp != 0 (incl. S=1) can't sequence-shard; the allreduce schedule
    # must stay correct through the hierarchical composition on every
    # factorization.
    w_sq = jax.random.normal(ks[4], (d, d)) * 0.1

    @given(topo=st.sampled_from(FACTORIZATIONS),
           mode=st.sampled_from(["barrier", "cais"]),
           S=st.sampled_from([1, 3, 5]))
    def ragged(topo, mode, S):
        i, o = topo
        x = jax.random.normal(jax.random.key(200 + S), (2, S, d), jnp.float32)
        mesh2d = sharding.make_tp_mesh(i, o)
        ax = sharding.tp_axes(mesh2d)
        backend = get_backend(mode)
        y = jax.jit(sharding.shard_map(
            lambda xl, wl: backend.gemm_ar(xl, wl, ax, cais),
            mesh=mesh2d, in_specs=(P(None, None, ax), P(ax, None)),
            out_specs=P(None, None, None), check_vma=False))(x, w_sq)
        check(f"ragged.gemm_ar.{mode}.t{i}x{o}.S{S}",
              float(jnp.abs(y - x @ w_sq).max()), 1e-5)

    ragged()

    # ---------------- grouped-EP MoE: E < tp gets true EP on 2D -----------
    import dataclasses as _dc

    import repro.models.transformer as tr_mod

    cfg_moe = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=1, d_model=d, num_heads=4, num_kv_heads=4, head_dim=8,
        d_ff=d_ff, window=16)
    cfg_moe = cfg_moe.scaled(moe=_dc.replace(cfg_moe.moe,
                                             capacity_factor=8.0))
    E = cfg_moe.moe.num_experts
    assert E == 4, E                      # E=4 < tp=8: no flat EP backend
    x_moe = jax.random.normal(jax.random.key(3), (2, 64, d), jnp.float32)
    params_moe = tr_mod.init_block(jax.random.key(4), "attn", cfg_moe,
                                   jnp.float32)

    # flat tp=8 reference (E < tp replicated-expert fallback path)
    tpc_flat = tp_mod.TPContext(mesh=_flat_mesh(), backend="cais", cais=cais)
    ref_moe, ref_aux = tp_mod.sp_moe_ffn(
        tpc_flat, x_moe, params_moe["norm2"]["scale"], params_moe["ffn"],
        cfg_moe)
    for mode in ("barrier", "cais"):
        for (i, o) in ((2, 4), (4, 2)):   # E % tp_out == 0 in both
            if E % o:
                continue
            tpc2 = tp_mod.TPContext(mesh=sharding.make_tp_mesh(i, o),
                                    backend=mode, cais=cais)
            got, aux = tp_mod.sp_moe_ffn(
                tpc2, x_moe, params_moe["norm2"]["scale"],
                params_moe["ffn"], cfg_moe)
            check(f"grouped_ep.moe.{mode}.t{i}x{o}",
                  float(jnp.abs(got - ref_moe).max()), 1e-5)
            check(f"grouped_ep.moe.{mode}.t{i}x{o}.aux",
                  abs(float(aux) - float(ref_aux)), 1e-6)

    # dispatch proof: the all-to-all must only ever cross the slow tp_out
    # axis — experts are replicated across tp_in (grouped EP)
    a2a_axes = []

    class RecordingCAIS(CAISBackend):
        name = "cais-record"

        def a2a_expert_ffn(self, send, ffn, axis, cc):
            a2a_axes.append(axis)
            return super().a2a_expert_ffn(send, ffn, axis, cc)

    register_backend(RecordingCAIS())
    try:
        tpc_r = tp_mod.TPContext(mesh=sharding.make_tp_mesh(2, 4),
                                 backend="cais-record", cais=cais)
        got_r, _ = tp_mod.sp_moe_ffn(
            tpc_r, x_moe, params_moe["norm2"]["scale"], params_moe["ffn"],
            cfg_moe)
        check("grouped_ep.dispatch.parity",
              float(jnp.abs(got_r - ref_moe).max()), 1e-5)
        # the hier guard re-enters with the single slow axis: every concrete
        # (non-tuple) dispatch must name tp_out, never tp_in or the tuple
        concrete = [a for a in a2a_axes if not isinstance(a, tuple)]
        ok = (len(concrete) >= 1
              and all(a == sharding.TP_OUT_AXIS for a in concrete))
        check("grouped_ep.dispatch.tp_out_only", 0.0 if ok else 1.0)
    finally:
        unregister_backend("cais-record")

    # ---------------- full model: flat ring == 2D mesh (fwd + grads) ------
    cfg_full = get_arch("deepseek-7b").smoke().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128)
    cfg_full_gqa = cfg_full.scaled(num_kv_heads=2)
    tokens = jax.random.randint(jax.random.key(7), (2, 32), 0,
                                cfg_full.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    # E=8: EP-applicable on BOTH the flat ring (E % 8 == 0) and the 2D mesh
    # (grouped EP, E % tp_out == 0) so both runs take the period-graph path
    # and the aux statistic is computed identically
    cfg_full_moe = get_arch("mixtral-8x7b").smoke().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=8, head_dim=16,
        d_ff=64, window=16)
    cfg_full_moe = cfg_full_moe.scaled(moe=_dc.replace(
        cfg_full_moe.moe, num_experts=8, capacity_factor=8.0,
        group_size=1024))
    toks_moe = jax.random.randint(jax.random.key(8), (2, 32), 0,
                                  cfg_full_moe.vocab_size)
    batch_moe = {"tokens": toks_moe, "labels": toks_moe}

    def max_leaf_err(a, b):
        errs = jax.tree.map(
            lambda u, v: float(jnp.abs(u.astype(jnp.float32)
                                       - v.astype(jnp.float32)).max()), a, b)
        return max(jax.tree.leaves(errs))

    for label, cfg_f, batch_f in (("dense", cfg_full, batch),
                                  ("gqa", cfg_full_gqa, batch),
                                  ("moe", cfg_full_moe, batch_moe)):
        for mode in ("barrier", "cais"):
            rt = Runtime(compute_dtype="float32", remat=False, loss_chunk=16,
                         tp=TPConfig(mode=mode, chunks=2))
            model = build_model(cfg_f, rt)
            params = model.init(jax.random.key(0))
            outs = {}
            for name_, mesh_ in (("flat", _flat_mesh()),
                                 ("2d", sharding.make_tp_mesh(2, 4))):
                with sharding.use_mesh(mesh_):
                    outs[name_] = jax.jit(
                        jax.value_and_grad(model.loss))(params, batch_f)
            check(f"topo2d.{label}.{mode}",
                  abs(float(outs["flat"][0]) - float(outs["2d"][0])), 1e-6)
            check(f"topo2d.{label}.{mode}.grads",
                  max_leaf_err(outs["flat"][1], outs["2d"][1]), 1e-6)

    if FAILED:
        print("FAILED:", FAILED)
        sys.exit(1)
    print("ALL OK")


if __name__ == "__main__":
    main()
