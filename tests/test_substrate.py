"""Substrate tests: data determinism, optimizers, trainer loop,
checkpoint/restart determinism, fault-tolerance hooks, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.checkpoint import store
from repro.configs import SHAPES_BY_NAME, ShapeConfig, get_arch
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.watchdog import StepWatchdog
from repro.models import build_model
from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         constant_schedule, cosine_schedule, make_optimizer)
from repro.runtime import SMOKE
from repro.serve import Engine, Request, ServeConfig
from repro.train import Trainer, TrainerConfig, init_state, make_train_step

TINY = ShapeConfig("tiny", 16, 4, "train")


def tiny_setup(arch="internlm2-1.8b", opt_name="adamw"):
    cfg = get_arch(arch).smoke()
    model = build_model(cfg, SMOKE)
    opt = make_optimizer(opt_name, constant_schedule(1e-3))
    return cfg, model, opt


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_by_step():
    cfg = get_arch("deepseek-7b").smoke()
    a = make_batch(cfg, TINY, step=7)
    b = make_batch(cfg, TINY, step=7)
    c = make_batch(cfg, TINY, step=8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab_size
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_host_slice():
    cfg = get_arch("deepseek-7b").smoke()
    full = make_batch(cfg, TINY, step=3)
    part = make_batch(cfg, TINY, step=3, host_slice=slice(1, 3))
    np.testing.assert_array_equal(full["tokens"][1:3], part["tokens"])


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    """Both optimizers should drive a toy quadratic toward its optimum."""
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3), "m": jnp.zeros((4, 3))}
    opt = make_optimizer(name, constant_schedule(5e-2), weight_decay=0.0)
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    for step in range(300):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.apply(params, grads, state,
                                  jnp.asarray(step, jnp.int32))
    assert float(loss_fn(params)) < 1e-2, float(loss_fn(params))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    from repro.optim import global_norm
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(s(jnp.asarray(100))) < 2e-4


# ---------------------------------------------------------------------------
# train step + trainer
# ---------------------------------------------------------------------------


def test_train_step_reduces_loss():
    cfg, model, opt = tiny_setup()
    step = jax.jit(make_train_step(model, opt, SMOKE))
    state = init_state(model, opt, jax.random.key(0))
    batch = make_batch(cfg, TINY, 0)
    losses = []
    for _ in range(30):
        state, m = step(state, batch)   # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_microbatch_accumulation_matches_full():
    cfg, model, opt = tiny_setup()
    batch = make_batch(cfg, TINY, 0)
    s1 = init_state(model, opt, jax.random.key(0))
    s2 = jax.tree.map(jnp.copy, s1)
    full = jax.jit(make_train_step(model, opt, SMOKE, microbatches=1))
    micro = jax.jit(make_train_step(model, opt, SMOKE, microbatches=2))
    s1, m1 = full(s1, batch)
    s2, m2 = micro(s2, batch)
    # same data, same update (microbatches average to the same gradient —
    # up to clipping nonlinearity, loss must match closely)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_trainer_runs_and_checkpoints(tmp_path):
    cfg, model, opt = tiny_setup()
    tc = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                       log_every=100)
    tr = Trainer(model, opt, cfg, TINY, SMOKE, tc)
    tr.run()
    assert store.latest_step(str(tmp_path)) == 6
    assert len(tr.history) == 6


def test_checkpoint_restart_determinism(tmp_path):
    """Kill at step 4, restart, finish — identical params to uninterrupted."""
    cfg, model, opt = tiny_setup()

    # uninterrupted run to step 8
    tr_full = Trainer(model, opt, cfg, TINY, SMOKE,
                      TrainerConfig(total_steps=8, log_every=100))
    state_full = tr_full.run()

    # interrupted: run 4, checkpoint, new trainer restores and finishes
    d = str(tmp_path)
    tr_a = Trainer(model, opt, cfg, TINY, SMOKE,
                   TrainerConfig(total_steps=4, ckpt_dir=d, ckpt_every=4,
                                 log_every=100))
    tr_a.run()
    tr_b = Trainer(model, opt, cfg, TINY, SMOKE,
                   TrainerConfig(total_steps=8, ckpt_dir=d, ckpt_every=100,
                                 log_every=100))
    state_resumed = tr_b.run()
    assert any("restored" in e for e in tr_b.events)

    for a, b in zip(jax.tree.leaves(state_full["params"]),
                    jax.tree.leaves(state_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_crc_detects_corruption(tmp_path):
    cfg, model, opt = tiny_setup()
    state = init_state(model, opt, jax.random.key(0))
    path = store.save(str(tmp_path), state, step=1)
    # flip bytes in the arrays file
    arrays = os.path.join(path, "arrays.npz")
    data = bytearray(open(arrays, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(arrays, "wb").write(bytes(data))
    template = jax.eval_shape(lambda: state)
    with pytest.raises(Exception):
        store.restore(str(tmp_path), template)


def test_straggler_watchdog_detects_and_checkpoints(tmp_path):
    """Inject slow steps via the trainer clock; the watchdog must flag and
    drop a checkpoint for orchestrated restart."""
    cfg, model, opt = tiny_setup()
    times = iter([0.0, 0.1,            # step0: 0.1s
                  0.2, 0.3,            # step1: 0.1
                  0.4, 0.5,            # step2: 0.1
                  1.0, 2.0,            # step3: 1.0  (slow)
                  3.0, 4.0,            # step4: 1.0  (slow)
                  5.0, 6.0,            # step5: 1.0  (slow -> 3 strikes)
                  7.0, 7.1, 7.2, 7.3])
    tc = TrainerConfig(total_steps=7, ckpt_dir=str(tmp_path),
                       ckpt_every=1000, log_every=100,
                       straggler_threshold=2.0)
    tr = Trainer(model, opt, cfg, TINY, SMOKE, tc,
                 _clock=lambda: next(times))
    tr.run()
    assert any(e.startswith("straggler@") for e in tr.events), tr.events
    assert store.latest_step(str(tmp_path)) is not None


def test_watchdog_unit():
    wd = StepWatchdog(threshold=2.0, patience=2)
    assert not wd.observe(0, 1.0)
    assert not wd.observe(1, 1.0)
    assert not wd.observe(2, 5.0)     # strike 1
    assert wd.observe(3, 5.0)         # strike 2 -> flagged
    assert wd.flagged_steps == [2, 3]


# ---------------------------------------------------------------------------
# elastic resharding
# ---------------------------------------------------------------------------


def test_restore_reshards_to_new_sharding(tmp_path):
    cfg, model, opt = tiny_setup()
    state = init_state(model, opt, jax.random.key(0))
    store.save(str(tmp_path), state, step=1)
    template = jax.eval_shape(lambda: state)
    dev = jax.devices()[0]
    sharding_fn = lambda key, arr: jax.sharding.SingleDeviceSharding(dev)
    restored, _ = store.restore(str(tmp_path), template,
                                sharding_fn=sharding_fn)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_engine_serves_batches():
    cfg, model, opt = tiny_setup(arch="gemma3-1b")
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, cfg, SMOKE,
                 ServeConfig(max_batch=4, s_max=32))
    reqs = [Request(rid=i, prompt=np.arange(1, 6 + (i % 2)) % cfg.vocab_size,
                    max_new_tokens=4) for i in range(6)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 4 for r in out)
    # greedy decode is deterministic: same prompt -> same completion
    r1 = Request(rid=100, prompt=np.arange(1, 6), max_new_tokens=4)
    r2 = Request(rid=101, prompt=np.arange(1, 6), max_new_tokens=4)
    eng.run([r1])
    eng.run([r2])
    assert r1.out_tokens == r2.out_tokens
