"""Dry-run smoke subset (deliverable e): a fast sample of cells must lower +
compile on the production meshes. The full 80-cell sweep runs via
``python -m repro.launch.dryrun`` (see EXPERIMENTS.md §Dry-run); this test
keeps the machinery honest in CI without the 45-minute sweep."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).parent.parent

CELLS = [
    ("whisper-tiny", "decode_32k", "single"),
    ("whisper-tiny", "train_4k", "multi"),
    ("gemma3-1b", "long_500k", "single"),
    ("mamba2-130m", "decode_32k", "multi"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_dryrun_cell(arch, shape, mesh, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)   # dryrun.py sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=1800,
        cwd=str(REPO))
    sys.stdout.write(proc.stdout[-2000:])
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-2000:]
    rec = json.load(open(next(tmp_path.glob("*.json"))))
    assert rec["status"] == "ok"
    assert rec["hlo_analysis"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    # memory must fit a v5e chip (16 GB HBM)
    total = rec["memory"]["total_hbm_bytes"]
    assert total < 16 * 1024**3, f"does not fit HBM: {total/2**30:.1f} GiB"
