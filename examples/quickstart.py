"""Quickstart: build an assigned architecture at smoke scale, run one
training step and a short greedy decode — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral-8x7b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import make_batch
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import constant_schedule, make_optimizer
from repro.runtime import SMOKE
from repro.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()      # reduced config, same family
    model = build_model(cfg, SMOKE)
    print(f"arch={cfg.name} family={cfg.family} "
          f"full-size params={get_arch(args.arch).param_count():,}")

    # --- one training step ---
    opt = make_optimizer(cfg.optimizer, constant_schedule(1e-3))
    step = jax.jit(make_train_step(model, opt, SMOKE))
    state = init_state(model, opt, jax.random.key(0))
    shape = ShapeConfig("tiny", 32, 4, "train")
    batch = make_batch(cfg, shape, step=0)
    state, metrics = step(state, batch)
    print(f"train: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # --- prefill + greedy decode ---
    b = make_batch(cfg, ShapeConfig("p", 8, 2, "train"), step=1)
    b.pop("labels")
    logits, caches = jax.jit(
        lambda p, bb: model.prefill(p, bb, s_max=16))(state["params"], b)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    idx = jnp.full((2,), 8 + cfg.num_prefix_tokens, jnp.int32)
    decode = jax.jit(model.decode_step)
    out = []
    for t in range(4):
        logits, caches = decode(state["params"], tok, caches, idx + t)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print(f"decode: generated tokens {out}")


if __name__ == "__main__":
    main()
