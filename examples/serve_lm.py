"""Batched serving example: a small LM behind the Engine — mixed prompt
lengths, greedy + temperature sampling, per-request outputs.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.runtime import SMOKE
from repro.serve import Engine, Request, ServeConfig


def main():
    cfg = get_arch("gemma3-1b").smoke()   # 5:1 local:global at smoke scale
    model = build_model(cfg, SMOKE)
    params = model.init(jax.random.key(0))

    eng = Engine(model, params, cfg, SMOKE, ServeConfig(max_batch=4, s_max=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(0, rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=8),
        Request(1, rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=8, temperature=0.8),
        Request(2, rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
                max_new_tokens=6),
        Request(3, rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
                max_new_tokens=6),
        Request(4, rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=10),
    ]
    eng.run(reqs, key=jax.random.key(7))
    for r in reqs:
        kind = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"request {r.rid} ({kind}, prompt {len(r.prompt)} toks) "
              f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()
