"""End-to-end training driver: a ~100M-parameter decoder-only LM through the
full production stack — data pipeline, AdamW + cosine schedule, remat,
checkpointing with deterministic restart, straggler watchdog.

Default flags are sized to finish quickly on one CPU core; pass
``--preset 100m --steps 300`` for the full-size run on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import Runtime
from repro.train import Trainer, TrainerConfig

PRESETS = {
    # ~100M params: 12L × d768 × ffn3072, 32k vocab (untied head)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=3072, vocab_size=32_000, batch=32, seq=1024),
    # CPU-friendly: ~8M params
    "tiny": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
                 d_ff=1024, vocab_size=8_192, batch=8, seq=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ArchConfig(
        name=f"repro-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab_size"], tie_embeddings=True)
    shape = ShapeConfig("train", p["seq"], p["batch"], "train")
    rt = Runtime(compute_dtype="float32", remat=False, loss_chunk=128)
    model = build_model(cfg, rt)
    print(f"params: {cfg.param_count():,}")

    opt = make_optimizer(
        "adamw", cosine_schedule(args.lr, warmup=max(args.steps // 10, 5),
                                 total=args.steps))
    tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 3, 10), log_every=5)
    trainer = Trainer(model, opt, cfg, shape, rt, tc, DataConfig(seed=0))
    trainer.run()
    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
