"""CAIS in action: the paper's L2 sub-layer (GEMM→RS→LN→AG→GEMM) through the
graph-level dataflow optimizer, executed on an 8-virtual-device TP ring.

Shows (1) the fusion the optimizer performs, (2) numerics identical to the
barrier schedule, (3) the HLO collective census — barrier mode lowers to
all-gather/reduce-scatter phase ops, CAIS mode to collective-permute chains
interleaved with partial dots (the fine-grained overlap).

    PYTHONPATH=src python examples/cais_sublayer.py
(re-executes itself with XLA_FLAGS for 8 virtual devices)
"""
import os
import re
import subprocess
import sys

_CHILD = "_REPRO_EXAMPLE_CHILD"


def child():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import sharding
    from repro.core import dataflow as df
    from repro.core.primitives import CAISConfig

    g = df.sublayer_graph()
    opt = df.optimize(g)
    print("graph:     ", " -> ".join(n.op for n in g.nodes if n.op != "input"))
    print("optimized: ", " -> ".join(n.op for n in opt.nodes
                                     if n.op != "input"))

    mesh = sharding.make_mesh((8,), ("model",))
    B, S, d, F = 2, 256, 128, 256
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (B, S, d))
    w = {"w1": jax.random.normal(ks[1], (d, F)) * 0.05,
         "scale": jax.random.normal(ks[2], (F,)) * 0.1,
         "w2": jax.random.normal(ks[3], (F, d)) * 0.05}

    def make(graph, chunks):
        def local(x, w1, scale, w2):
            return df.execute(graph, {"x": x},
                              {"w1": w1, "scale": scale, "w2": w2},
                              axis="model",
                              cais=CAISConfig(num_chunks=chunks))
        return jax.jit(sharding.shard_map(
            local, mesh=mesh,
            in_specs=(P(None, None, "model"), P("model", None), P(),
                      P(None, "model")),
            out_specs=(P(None, None, "model"),), check_vma=False))

    ref = df.execute(g, {"x": x}, w)[0]
    for name, graph in (("barrier", g), ("cais-fused", opt)):
        fn = make(graph, chunks=4)
        out = fn(x, w["w1"], w["scale"], w["w2"])[0]
        err = float(jnp.abs(out - ref).max())
        hlo = fn.lower(x, w["w1"], w["scale"], w["w2"]).compile().as_text()
        census = {k: len(re.findall(rf"= \S+ {k}\(", hlo))
                  for k in ("all-gather", "reduce-scatter",
                            "collective-permute")}
        print(f"{name:12s} maxerr={err:.2e} hlo={census}")


def main():
    if os.environ.get(_CHILD):
        child()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD] = "1"
    env.setdefault("PYTHONPATH", "src")
    code = ("import examples.cais_sublayer as m; m.child()"
            if os.path.exists("examples/__init__.py") else
            "import sys; sys.path.insert(0, 'examples'); "
            "import cais_sublayer; cais_sublayer.child()")
    r = subprocess.run([sys.executable, "-c", code], env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
